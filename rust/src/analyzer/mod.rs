//! The analyzer — Algorithm 2.
//!
//! A_{N,k,n}(y_1, …, y_{mn}): z̄ ← Σ y_i mod N; then the range decision —
//! if z̄ > 2nk return 0, else if z̄ > nk return n, else return z̄/k. The
//! decision rule folds pre-randomizer noise that pushed the sum outside
//! the feasible range [0, nk] back to the nearest feasible output, using
//! the odd modulus to split the infeasible arc evenly between "wrapped
//! below 0" (→ 0) and "wrapped above n" (→ n).

#![deny(clippy::redundant_clone)]

use crate::arith::fixed::FixedCodec;
use crate::arith::modring::ModRing;

/// Analyzer instance for fixed (N, k, n).
#[derive(Clone, Copy, Debug)]
pub struct Analyzer {
    ring: ModRing,
    codec: FixedCodec,
    n: usize,
}

impl Analyzer {
    /// Panics if N is even. The paper also wants N > 3nk so the three
    /// decision arcs are disjoint; we check it here.
    pub fn new(modulus: u64, scale: u64, n: usize) -> Self {
        let nk = (n as u128) * (scale as u128);
        assert!(
            (modulus as u128) > 3 * nk,
            "Algorithm 2 requires N > 3nk (N={modulus}, nk={nk})"
        );
        Analyzer { ring: ModRing::new(modulus), codec: FixedCodec::new(scale), n }
    }

    /// Like `new` but without the N > 3nk assertion — used by benches that
    /// deliberately explore infeasible corners.
    pub fn new_unchecked(modulus: u64, scale: u64, n: usize) -> Self {
        Analyzer { ring: ModRing::new(modulus), codec: FixedCodec::new(scale), n }
    }

    pub fn ring(&self) -> ModRing {
        self.ring
    }

    /// The raw modular sum z̄ (before the decision rule) — the quantity the
    /// Theorem 2 path reads out exactly.
    pub fn raw_sum(&self, messages: &[u64]) -> u64 {
        self.ring.sum(messages)
    }

    /// Algorithm 2's decision rule applied to a raw sum.
    pub fn decide(&self, zbar: u64) -> f64 {
        let nk = self.n as u64 * self.codec.scale();
        if zbar > 2 * nk {
            0.0
        } else if zbar > nk {
            self.n as f64
        } else {
            self.codec.decode_sum(zbar)
        }
    }

    /// Full analyzer: sum then decide.
    pub fn analyze(&self, messages: &[u64]) -> f64 {
        self.decide(self.raw_sum(messages))
    }

    /// Vectorized analyzer over a flat (rows, d) column-major-by-coordinate
    /// layout: coordinate j's messages are `flat[j*rows..(j+1)*rows]`.
    pub fn analyze_columns(&self, flat: &[u64], rows: usize) -> Vec<f64> {
        assert!(rows > 0 && flat.len() % rows == 0);
        flat.chunks_exact(rows).map(|col| self.analyze(col)).collect()
    }

    /// Validating analyzer: rejects malformed batches instead of silently
    /// mis-summing — the failure-injection path the coordinator uses when
    /// ingesting untrusted transports. Checks every residue is in Z_N and
    /// the message count is a multiple of m (each user sends exactly m).
    pub fn analyze_checked(
        &self,
        messages: &[u64],
        num_messages: usize,
    ) -> Result<f64, AnalyzeError> {
        if num_messages == 0 || messages.len() % num_messages != 0 {
            return Err(AnalyzeError::BadCount { len: messages.len(), m: num_messages });
        }
        if let Some(pos) = messages.iter().position(|&y| y >= self.ring.modulus()) {
            return Err(AnalyzeError::OutOfRing { index: pos, value: messages[pos] });
        }
        Ok(self.analyze(messages))
    }
}

/// Validation failures from [`Analyzer::analyze_checked`].
#[derive(Debug, PartialEq)]
pub enum AnalyzeError {
    BadCount { len: usize, m: usize },
    OutOfRing { index: usize, value: u64 },
}

impl std::fmt::Display for AnalyzeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AnalyzeError::BadCount { len, m } => {
                write!(f, "message count {len} is not a multiple of m = {m}")
            }
            AnalyzeError::OutOfRing { index, value } => {
                write!(f, "message at index {index} = {value} is outside Z_N")
            }
        }
    }
}

impl std::error::Error for AnalyzeError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::CloakEncoder;
    use crate::rng::{ChaCha20Rng, SeedableRng};
    use crate::util::proptest_lite::{forall, Gen};

    #[test]
    fn decision_rule_cases() {
        // N=701 > 3*10*20=600? 3nk = 3*10*20 = 600 => need N>600, pick 701.
        let a = Analyzer::new(701, 20, 10);
        let nk = 200u64;
        assert_eq!(a.decide(0), 0.0);
        assert_eq!(a.decide(nk), 10.0);
        assert_eq!(a.decide(nk + 1), 10.0); // wrapped above
        assert_eq!(a.decide(2 * nk), 10.0);
        assert_eq!(a.decide(2 * nk + 1), 0.0); // wrapped below
        assert_eq!(a.decide(100), 5.0);
    }

    #[test]
    #[should_panic(expected = "N > 3nk")]
    fn rejects_small_modulus() {
        Analyzer::new(599, 20, 10);
    }

    #[test]
    fn prop_encode_shuffle_analyze_exact() {
        // Theorem 2 zero-noise path: the analyzer recovers the exact
        // discretized sum for any inputs, any valid parameters.
        forall("pipeline exactness", 100, |g: &mut Gen| {
            let n = g.usize_in(2, 60);
            let scale = 1 + g.u64_below(100);
            let m = g.usize_in(4, 12);
            let min_mod = 3 * n as u64 * scale + 1;
            let modulus = {
                let v = min_mod + g.u64_below(1 << 20);
                if v % 2 == 0 {
                    v + 1
                } else {
                    v
                }
            };
            let enc = CloakEncoder::new(modulus, scale, m);
            let ana = Analyzer::new(modulus, scale, n);
            let mut rng = ChaCha20Rng::seed_from_u64(g.seed());
            let xs: Vec<f64> = (0..n).map(|_| g.f64_unit()).collect();
            let mut messages = Vec::with_capacity(n * m);
            let mut truth_bar = 0u64;
            for &x in &xs {
                truth_bar += enc.codec().encode(x);
                messages.extend(enc.encode_scalar(x, &mut rng));
            }
            // shuffle must not matter: reverse + interleave
            messages.reverse();
            let est = ana.analyze(&messages);
            assert!((est - truth_bar as f64 / scale as f64).abs() < 1e-9);
        });
    }

    #[test]
    fn analyze_columns_layout() {
        let a = Analyzer::new(2401, 20, 4); // 3nk=240
        // two coordinates, 3 messages each
        let flat = vec![10, 20, 30, 5, 5, 5];
        let out = a.analyze_columns(&flat, 3);
        assert_eq!(out, vec![3.0, 0.75]);
    }

    #[test]
    fn checked_rejects_malformed_batches() {
        let a = Analyzer::new(2401, 20, 4);
        // wrong multiplicity
        assert_eq!(
            a.analyze_checked(&[1, 2, 3], 2),
            Err(AnalyzeError::BadCount { len: 3, m: 2 })
        );
        assert_eq!(
            a.analyze_checked(&[1, 2], 0),
            Err(AnalyzeError::BadCount { len: 2, m: 0 })
        );
        // out-of-ring residue (e.g. a corrupted or hostile transport)
        assert_eq!(
            a.analyze_checked(&[1, 2401], 2),
            Err(AnalyzeError::OutOfRing { index: 1, value: 2401 })
        );
        // well-formed batch passes through to the normal analyzer
        assert_eq!(a.analyze_checked(&[10, 10], 2).unwrap(), 1.0);
    }

    #[test]
    fn wraparound_noise_clamps() {
        // Simulate noise pushing the sum just below zero: z = -3 mod N.
        let a = Analyzer::new(2401, 20, 4);
        let ring = a.ring();
        let zbar = ring.from_i64(-3);
        assert_eq!(a.decide(zbar), 0.0);
        // and just above nk:
        let zbar2 = 4 * 20 + 5;
        assert_eq!(a.decide(zbar2), 4.0);
    }
}
