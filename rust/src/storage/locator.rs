//! `Locator`-keyed artifact store + FedAvg campaign checkpoints.
//!
//! The store follows the aleo-setup disk coordinator's scheme: every
//! persistent artifact has a [`Locator`] naming it, the store maps
//! locators to files under one root, and writes are atomic (tmp file +
//! fsync + rename) so a crash mid-write can never leave a half-written
//! artifact under a real name — readers see the old version or the new
//! one, nothing in between. The round journal gets its durability from
//! append-only framing instead (see [`journal`](super::journal)); the
//! store is for whole-file artifacts that are replaced, not appended.
//!
//! [`CampaignCheckpoint`] is the FL driver's between-rounds snapshot:
//! everything needed to resume a FedAvg campaign on a fresh coordinator —
//! model weights, optimizer velocity, rounds done, the config fingerprint
//! (so a checkpoint cannot resume under a drifted plan) and the campaign
//! seed. Serialization is the crate's usual hand-rolled little-endian
//! layout with an FNV-1a trailer; f32s travel as raw bits so a
//! checkpoint→resume round trip is bit-exact.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::transport::wire::fnv1a32;
use crate::util::error::{Context as _, Result};

/// A durable artifact's name — the single place on-disk layout is decided.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Locator {
    /// The campaign's append-only round journal.
    RoundJournal,
    /// The FedAvg checkpoint taken after `round` rounds completed.
    Checkpoint { round: u64 },
}

impl Locator {
    /// The file name this locator resolves to under a store root.
    pub fn file_name(&self) -> String {
        match self {
            Locator::RoundJournal => "round_journal.wal".to_string(),
            Locator::Checkpoint { round } => format!("checkpoint_{round:08}.bin"),
        }
    }
}

/// A directory of locator-addressed artifacts with atomic replacement.
pub struct Store {
    root: PathBuf,
}

impl Store {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn new(root: impl Into<PathBuf>) -> Result<Store> {
        let root = root.into();
        fs::create_dir_all(&root)
            .with_context(|| format!("creating store root {}", root.display()))?;
        Ok(Store { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The on-disk path for `loc` — handed to [`RoundJournal`]
    /// (journal appends bypass the atomic-replace path by design).
    ///
    /// [`RoundJournal`]: super::RoundJournal
    pub fn path(&self, loc: &Locator) -> PathBuf {
        self.root.join(loc.file_name())
    }

    pub fn exists(&self, loc: &Locator) -> bool {
        self.path(loc).exists()
    }

    /// Atomically replace `loc` with `bytes`: write a tmp file, fsync it,
    /// rename over the real name. A crash anywhere in that sequence
    /// leaves either the old artifact or the new one, never a torn mix.
    pub fn write(&self, loc: &Locator, bytes: &[u8]) -> Result<()> {
        let name = loc.file_name();
        let tmp = self.root.join(format!("{name}.tmp"));
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_data().with_context(|| format!("syncing {}", tmp.display()))?;
        }
        fs::rename(&tmp, self.path(loc)).with_context(|| format!("publishing {name}"))?;
        Ok(())
    }

    pub fn read(&self, loc: &Locator) -> Result<Vec<u8>> {
        fs::read(self.path(loc)).with_context(|| format!("reading {}", self.path(loc).display()))
    }

    /// The highest checkpoint round present, scanning the store root.
    pub fn latest_checkpoint(&self) -> Option<u64> {
        let entries = fs::read_dir(&self.root).ok()?;
        let mut best: Option<u64> = None;
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(digits) =
                name.strip_prefix("checkpoint_").and_then(|r| r.strip_suffix(".bin"))
            {
                if let Ok(round) = digits.parse::<u64>() {
                    best = Some(best.map_or(round, |b| b.max(round)));
                }
            }
        }
        best
    }

    pub fn write_checkpoint(&self, ckpt: &CampaignCheckpoint) -> Result<()> {
        self.write(&Locator::Checkpoint { round: ckpt.rounds_done }, &ckpt.to_bytes())
    }

    pub fn read_checkpoint(&self, round: u64) -> Result<CampaignCheckpoint> {
        CampaignCheckpoint::from_bytes(&self.read(&Locator::Checkpoint { round })?)
    }

    /// The newest readable checkpoint, if any exists.
    pub fn read_latest_checkpoint(&self) -> Result<Option<CampaignCheckpoint>> {
        match self.latest_checkpoint() {
            Some(round) => Ok(Some(self.read_checkpoint(round)?)),
            None => Ok(None),
        }
    }
}

/// Checkpoint serialization version (first byte of every checkpoint).
pub const CHECKPOINT_VERSION: u8 = 1;

/// Everything a FedAvg campaign needs to resume on a fresh coordinator.
///
/// Layout (little-endian, FNV-1a 32 trailer over all preceding bytes):
///
/// ```text
/// ver:u8 | rounds_done:u64 | steps:u64 | config_fnv:u32 | seed:u64
///   | nparams:u32 | params[n]:f32-bits | velocity[n]:f32-bits | fnv:u32
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignCheckpoint {
    /// Aggregation rounds completed (the resumed stack fast-forwards here).
    pub rounds_done: u64,
    /// Optimizer steps taken (equals `rounds_done` for the plain driver).
    pub steps: u64,
    /// Fingerprint of the engine config the campaign runs under — resume
    /// refuses a checkpoint taken under a different plan.
    pub config_fnv: u32,
    /// The campaign seed (client seed derivation + engine randomness).
    pub seed: u64,
    /// Model weights after `rounds_done` rounds.
    pub params: Vec<f32>,
    /// Momentum velocity after `rounds_done` rounds (same length).
    pub velocity: Vec<f32>,
}

impl CampaignCheckpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let n = self.params.len();
        debug_assert_eq!(n, self.velocity.len());
        let mut out = Vec::with_capacity(1 + 8 + 8 + 4 + 8 + 4 + 8 * n + 4);
        out.push(CHECKPOINT_VERSION);
        out.extend_from_slice(&self.rounds_done.to_le_bytes());
        out.extend_from_slice(&self.steps.to_le_bytes());
        out.extend_from_slice(&self.config_fnv.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&(n as u32).to_le_bytes());
        for p in &self.params {
            out.extend_from_slice(&p.to_bits().to_le_bytes());
        }
        for v in &self.velocity {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let crc = fnv1a32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<CampaignCheckpoint> {
        const HEADER: usize = 1 + 8 + 8 + 4 + 8 + 4;
        crate::ensure!(bytes.len() >= HEADER + 4, "checkpoint too short: {} bytes", bytes.len());
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let want = crate::util::bytes::le_u32(crc_bytes);
        let got = fnv1a32(body);
        crate::ensure!(got == want, "checkpoint checksum mismatch: {got:#010x} != {want:#010x}");
        let mut r = Reader { b: body, at: 0 };
        let ver = r.u8()?;
        crate::ensure!(
            ver == CHECKPOINT_VERSION,
            "checkpoint version {ver} (this build reads {CHECKPOINT_VERSION})"
        );
        let rounds_done = r.u64()?;
        let steps = r.u64()?;
        let config_fnv = r.u32()?;
        let seed = r.u64()?;
        let n = r.u32()? as usize;
        // Overflow-safe length check, same screen as the wire decoders.
        crate::ensure!(
            (body.len() - r.at) as u128 == n as u128 * 8,
            "checkpoint claims {n} params but carries {} payload bytes",
            body.len() - r.at
        );
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            params.push(f32::from_bits(r.u32()?));
        }
        let mut velocity = Vec::with_capacity(n);
        for _ in 0..n {
            velocity.push(f32::from_bits(r.u32()?));
        }
        Ok(CampaignCheckpoint { rounds_done, steps, config_fnv, seed, params, velocity })
    }
}

struct Reader<'a> {
    b: &'a [u8],
    at: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        crate::ensure!(self.at + n <= self.b.len(), "checkpoint truncated at byte {}", self.at);
        let s = &self.b[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(crate::util::bytes::le_u32(self.take(4)?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(crate::util::bytes::le_u64(self.take(8)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{forall, Gen};

    fn tmp_root(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cloak_store_{}_{tag}", std::process::id()));
        p
    }

    fn sample(rounds_done: u64, n: usize) -> CampaignCheckpoint {
        CampaignCheckpoint {
            rounds_done,
            steps: rounds_done,
            config_fnv: 0xdead_beef,
            seed: 42,
            params: (0..n).map(|i| i as f32 * 0.25 - 1.0).collect(),
            velocity: (0..n).map(|i| -(i as f32) * 0.125).collect(),
        }
    }

    #[test]
    fn store_write_read_replace() {
        let root = tmp_root("rw");
        let store = Store::new(&root).unwrap();
        let loc = Locator::Checkpoint { round: 3 };
        assert!(!store.exists(&loc));
        store.write(&loc, b"one").unwrap();
        assert!(store.exists(&loc));
        assert_eq!(store.read(&loc).unwrap(), b"one");
        store.write(&loc, b"two").unwrap();
        assert_eq!(store.read(&loc).unwrap(), b"two");
        // No tmp residue after a clean publish.
        assert!(!root.join(format!("{}.tmp", loc.file_name())).exists());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn latest_checkpoint_picks_the_max() {
        let root = tmp_root("latest");
        let store = Store::new(&root).unwrap();
        assert_eq!(store.latest_checkpoint(), None);
        for round in [2u64, 11, 5] {
            store.write_checkpoint(&sample(round, 4)).unwrap();
        }
        assert_eq!(store.latest_checkpoint(), Some(11));
        let back = store.read_latest_checkpoint().unwrap().unwrap();
        assert_eq!(back, sample(11, 4));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn checkpoint_roundtrip_bit_exact() {
        let mut c = sample(7, 5);
        // Adversarial f32s: the round trip must be raw-bits exact.
        c.params[0] = f32::MIN_POSITIVE;
        c.params[1] = -0.0;
        c.velocity[2] = 1e38;
        let back = CampaignCheckpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back, c);
        for (a, b) in c.params.iter().zip(&back.params) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in c.velocity.iter().zip(&back.velocity) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn prop_checkpoint_corruption_detected() {
        forall("checkpoint corruption", 120, |g: &mut Gen| {
            let n = g.usize_in(1, 12);
            let c = CampaignCheckpoint {
                rounds_done: g.seed(),
                steps: g.seed(),
                config_fnv: g.u64_below(u32::MAX as u64) as u32,
                seed: g.seed(),
                params: (0..n).map(|_| g.f64_unit() as f32).collect(),
                velocity: (0..n).map(|_| -(g.f64_unit() as f32)).collect(),
            };
            let clean = c.to_bytes();
            assert_eq!(CampaignCheckpoint::from_bytes(&clean).unwrap(), c);
            let mut bad = clean.clone();
            let pos = g.usize_in(0, bad.len() - 1);
            bad[pos] ^= 1 << g.usize_in(0, 7);
            assert!(CampaignCheckpoint::from_bytes(&bad).is_err(), "bit flip at {pos} accepted");
            // Truncation at any point is rejected too.
            let cut = g.usize_in(0, clean.len() - 1);
            assert!(CampaignCheckpoint::from_bytes(&clean[..cut]).is_err());
        });
    }

    #[test]
    fn checkpoint_version_screened() {
        let mut bytes = sample(1, 2).to_bytes();
        bytes[0] = 9;
        // Re-stamp the checksum so only the version differs.
        let total = bytes.len();
        let crc = fnv1a32(&bytes[..total - 4]);
        bytes[total - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = CampaignCheckpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");
    }
}
