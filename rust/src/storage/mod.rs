//! Durable round state — the append-only journal and keyed checkpoint
//! store that make a coordinator crash survivable.
//!
//! # Architecture
//!
//! ```text
//!   DurableCoordinator (coordinator::durable)
//!        │  write-ahead: journal every transition BEFORE acting on it
//!        ▼
//!   RoundJournal (storage::journal)         Store + Locator (storage::locator)
//!        │  append-only wire frames              │  keyed whole-file artifacts
//!        ▼                                       ▼
//!   round_journal.wal                       checkpoint_<round>.bin
//! ```
//!
//! [`RoundJournal`] is an append-only log of [`wire`](crate::transport::wire)
//! frames — the SAME length-prefixed, FNV-checksummed codec the cluster
//! links speak, so one decoder serves sockets and disk alike. A journal
//! replay walks the file with `decode_frame`; the first undecodable byte
//! (torn tail from a crash mid-`write`, flipped bit from a bad sector)
//! ends the log, and `open` truncates the file back to the last clean
//! record boundary. [`Store`] is a `Locator`-keyed whole-file store
//! (atomic tmp-file + rename writes) for FedAvg campaign checkpoints
//! ([`CampaignCheckpoint`]), following the aleo-setup disk coordinator's
//! locator scheme.
//!
//! # What is journaled, and what is derivable
//!
//! One round's journal records, in append order:
//!
//! | record                   | frame                     | why |
//! |--------------------------|---------------------------|-----|
//! | round manifest           | `Hello` + `ShardReady`    | round id, cohort size, config fingerprint |
//! | issued work units        | `ShardWork` / `ShardPool` | the write-ahead: everything a shard needs |
//! | client events (streaming)| `Contribute` / `ContributeBatch` / `Drop` | accepted traffic, verbatim bytes |
//! | per-unit outputs (recovery) | `ShardOut` (real shard id) | incremental recovery progress |
//! | merged estimates         | `ShardOut` with [`MERGED_SHARD`] | the round's result |
//! | round commit             | `Commit` (fsync barrier)  | the round is done; replay skips it |
//!
//! Everything else is *derivable* and deliberately NOT journaled: client
//! shares are a pure function of `(client, instance, round)` seeds, the
//! shuffle seed chain derives from `(engine seed, round, shard)`, and
//! work units carry all of those seeds already (the property the cluster
//! layer's retry/resend paths rely on). So the journal stores one copy
//! of each input value and zero randomness.
//!
//! # Why replay is bit-identical
//!
//! Re-executing a journaled work unit through
//! [`ShardExecutor`](crate::engine::ShardExecutor) reproduces the exact
//! estimates of the uninterrupted run: encode streams are seeded per
//! `(client, instance, round)` (all in the work unit), and the analyzer's
//! modular sum is permutation-invariant, so the mixnet permutation — the
//! only place the executing shard's identity enters — is invisible in
//! the estimates. The same argument makes recovery indifferent to the
//! engine's internal shard tiling: ANY contiguous tiling of the instance
//! range merges to the same result (see `ShardRoundWork::slice`), so the
//! journal's work units need not match how the crashed engine happened
//! to partition the round.
//!
//! # Trust model
//!
//! The journal lives on the coordinator's own disk and holds exactly what
//! the coordinator already knows — client values (encode path) or cloaked
//! shares (streaming path). It never stores anything the analyzer could
//! not see; durability adds no new observer. Checkpoints store model
//! weights and optimizer state, which the FL server owns in memory anyway.

#![deny(clippy::redundant_clone)]

pub mod journal;
pub mod locator;

pub use journal::RoundJournal;
pub use locator::{CampaignCheckpoint, Locator, Store, CHECKPOINT_VERSION};

/// Sentinel shard id marking a journaled `ShardOut` frame as the round's
/// FINAL merged estimates (all instances), distinguishing it from the
/// per-work-unit outputs recovery journals under real shard ids.
pub const MERGED_SHARD: u32 = u32::MAX;
