//! The append-only round journal: wire frames on disk.
//!
//! A journal is a single file of concatenated
//! [`wire`](crate::transport::wire) frames, written by exactly one
//! coordinator and only ever appended to. That single-writer/append-only
//! discipline is what makes torn-tail recovery sound: the first byte that
//! fails to decode (length prefix cut short, checksum mismatch from a
//! half-flushed record) can only be the crash frontier, so everything
//! before it is a complete record and everything after it is trash —
//! [`RoundJournal::open`] truncates the file back to that boundary and
//! hands the clean prefix to the caller for replay.
//!
//! Append durability is tiered: ordinary records are buffered writes
//! (the OS flushes them well before a process crash loses them; a kernel
//! crash costs at most the uncommitted tail, which recovery re-derives),
//! while [`Frame::Commit`] records — the "this round is done" barrier —
//! fsync before returning, so a committed round can never be replayed
//! into a different result.

use std::fs::OpenOptions;
use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
use std::path::{Path, PathBuf};

use crate::telemetry::{EventKind, EventRecord, Tracer};
use crate::transport::wire::{decode_frame, encode_frame, Frame};
use crate::util::error::{Context as _, Result};

/// An open, appendable round journal. See the module docs for the
/// durability contract.
pub struct RoundJournal {
    file: std::fs::File,
    path: PathBuf,
    bytes: u64,
    /// Flight recorder for append/commit events (noop default — the
    /// durable coordinator installs the aggregator's tracer). Events
    /// carry record sizes and round ids only, never record contents.
    tracer: Tracer,
}

impl RoundJournal {
    /// Start a fresh journal at `path`, truncating any existing file —
    /// the "new campaign" entry point. Use [`RoundJournal::open`] to
    /// preserve and replay an existing log.
    pub fn create(path: impl Into<PathBuf>) -> Result<RoundJournal> {
        let path = path.into();
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        Ok(RoundJournal { file, path, bytes: 0, tracer: Tracer::noop() })
    }

    /// Open (or create) the journal at `path`, replaying every complete
    /// record and truncating a torn tail. Returns the journal positioned
    /// for appends, the decoded records in append order, and how many
    /// trailing bytes were dropped as torn (0 for a clean shutdown).
    pub fn open(path: impl Into<PathBuf>) -> Result<(RoundJournal, Vec<Frame>, u64)> {
        let path = path.into();
        let mut file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        let mut buf = Vec::new();
        file.read_to_end(&mut buf)
            .with_context(|| format!("reading journal {}", path.display()))?;
        let mut frames = Vec::new();
        let mut off = 0usize;
        while off < buf.len() {
            match decode_frame(&buf[off..]) {
                Ok((frame, used)) => {
                    frames.push(frame);
                    off += used;
                }
                // Single writer, append-only: the first undecodable byte
                // is the crash frontier — drop it and everything after.
                Err(_) => break,
            }
        }
        let dropped = (buf.len() - off) as u64;
        if dropped > 0 {
            file.set_len(off as u64).context("truncating torn journal tail")?;
        }
        file.seek(SeekFrom::Start(off as u64)).context("seeking journal end")?;
        let journal = RoundJournal { file, path, bytes: off as u64, tracer: Tracer::noop() };
        Ok((journal, frames, dropped))
    }

    /// Install a flight recorder: subsequent appends emit
    /// JournalAppend/JournalCommit events (sizes and round ids only).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Append one record. `Commit` records fsync before returning (the
    /// round-done barrier); everything else is a buffered write. The
    /// commit's telemetry event carries the measured fsync wall in
    /// `value` (nanoseconds — a public latency, the SLO watchdog's
    /// journal-health signal).
    pub fn append(&mut self, frame: &Frame) -> Result<()> {
        let bytes = encode_frame(frame);
        self.file
            .write_all(&bytes)
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        self.bytes += bytes.len() as u64;
        if matches!(frame, Frame::Commit { .. }) {
            let t0 = std::time::Instant::now();
            self.sync()?;
            let fsync_ns = t0.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64;
            self.tracer.record(
                EventRecord::new(EventKind::JournalCommit, frame_round(frame))
                    .with_bytes(bytes.len() as u64)
                    .with_value(fsync_ns as f64),
            );
        } else {
            self.tracer.record(
                EventRecord::new(EventKind::JournalAppend, frame_round(frame))
                    .with_bytes(bytes.len() as u64),
            );
        }
        Ok(())
    }

    /// Append an already-encoded frame verbatim — the streaming tap uses
    /// this to journal accepted client traffic without a re-encode.
    /// Rejects bytes that are not exactly one well-formed frame, so a bug
    /// in the caller can never poison the log.
    pub fn append_raw(&mut self, bytes: &[u8]) -> Result<()> {
        let (frame, used) = decode_frame(bytes).context("append_raw: not a valid frame")?;
        crate::ensure!(
            used == bytes.len(),
            "append_raw: {} trailing bytes after one frame",
            bytes.len() - used
        );
        self.file
            .write_all(bytes)
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        self.bytes += bytes.len() as u64;
        self.tracer.record(
            EventRecord::new(EventKind::JournalAppend, frame_round(&frame))
                .with_bytes(bytes.len() as u64),
        );
        Ok(())
    }

    /// Force buffered records to disk (the write-ahead barrier the
    /// durable coordinator takes after journaling a round's work units).
    pub fn sync(&mut self) -> Result<()> {
        self.file
            .sync_data()
            .with_context(|| format!("syncing journal {}", self.path.display()))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes of complete records currently in the journal.
    pub fn len_bytes(&self) -> u64 {
        self.bytes
    }
}

/// Round id a record belongs to, for telemetry attribution (0 for the
/// few control frames that carry none).
fn frame_round(frame: &Frame) -> u64 {
    match frame {
        Frame::Hello { round, .. }
        | Frame::Contribute { round, .. }
        | Frame::ContributeBatch { round, .. }
        | Frame::Drop { round, .. }
        | Frame::Commit { round, .. } => *round,
        Frame::ShardOut(m) => m.round,
        Frame::ShardWork(m) => m.round,
        Frame::ShardPool(m) => m.round,
        Frame::ShardAssign(_) | Frame::ShardReady(_) | Frame::ShardRetire(_) => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::ClientBatch;
    use crate::transport::wire::{ShardOutMsg, ShardWorkMsg};
    use crate::util::proptest_lite::{forall, Gen};

    fn tmp(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cloak_journal_{}_{tag}.wal", std::process::id()));
        p
    }

    /// Same harness shape as the wire codec's 0x01–0x0B prop tests: a
    /// random frame of the types a journal actually holds.
    fn gen_frame(g: &mut Gen) -> Frame {
        match g.usize_in(0, 5) {
            0 => Frame::Hello { round: g.seed(), client: g.u64_below(1 << 20) as u32 },
            1 => Frame::Contribute {
                round: g.seed(),
                batch: ClientBatch {
                    client_stream: g.u64_below(1 << 20) as u32,
                    shares: g.vec_below(u64::MAX, g.usize_in(0, 32)),
                },
            },
            2 => Frame::Drop { round: g.seed(), client: g.u64_below(1 << 20) as u32 },
            3 => Frame::Commit { round: g.seed(), participants: g.u64_below(1 << 20) as u32 },
            4 => Frame::ShardOut(ShardOutMsg {
                round: g.seed(),
                shard: g.u64_below(256) as u32,
                wall_ns: g.seed(),
                estimates: (0..g.usize_in(0, 8)).map(|_| g.f64_unit() * 1e6).collect(),
            }),
            _ => {
                let cohort = g.usize_in(1, 4);
                let span = g.usize_in(1, 3);
                Frame::ShardWork(ShardWorkMsg {
                    round: g.seed(),
                    shard: g.u64_below(256) as u32,
                    lo: g.u64_below(1 << 10) as u32,
                    span: span as u32,
                    shard_seed: g.seed(),
                    client_round_seeds: g.vec_below(u64::MAX, cohort),
                    values: (0..span * cohort).map(|_| g.f64_unit()).collect(),
                })
            }
        }
    }

    #[test]
    fn roundtrip_across_reopen() {
        let path = tmp("roundtrip");
        let frames = vec![
            Frame::Hello { round: 0, client: 12 },
            Frame::Commit { round: 0, participants: 12 },
            Frame::Hello { round: 1, client: 12 },
        ];
        {
            let mut j = RoundJournal::create(&path).unwrap();
            for f in &frames {
                j.append(f).unwrap();
            }
        }
        let (mut j, back, dropped) = RoundJournal::open(&path).unwrap();
        assert_eq!(back, frames);
        assert_eq!(dropped, 0);
        // Appends after a reopen land after the replayed records.
        j.append(&Frame::Commit { round: 1, participants: 10 }).unwrap();
        drop(j);
        let (_, back2, dropped2) = RoundJournal::open(&path).unwrap();
        assert_eq!(back2.len(), 4);
        assert_eq!(back2[..3], frames[..]);
        assert_eq!(dropped2, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_truncated_and_appendable() {
        // Satellite: recovery from a half-written trailing record. Build
        // the exact post-crash disk state — two clean records plus the
        // first half of a third — and require open() to recover the clean
        // prefix, truncate the file, and accept new appends.
        let path = tmp("torn");
        let clean = vec![
            Frame::Hello { round: 3, client: 7 },
            Frame::ShardOut(ShardOutMsg {
                round: 3,
                shard: 0,
                wall_ns: 5,
                estimates: vec![1.5, 2.5],
            }),
        ];
        let mut bytes = Vec::new();
        for f in &clean {
            bytes.extend_from_slice(&encode_frame(f));
        }
        let torn = encode_frame(&Frame::Commit { round: 3, participants: 7 });
        let clean_len = bytes.len();
        bytes.extend_from_slice(&torn[..torn.len() / 2]);
        std::fs::write(&path, &bytes).unwrap();

        let (mut j, back, dropped) = RoundJournal::open(&path).unwrap();
        assert_eq!(back, clean);
        assert_eq!(dropped, (torn.len() / 2) as u64);
        assert_eq!(j.len_bytes(), clean_len as u64);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), clean_len as u64);

        j.append(&Frame::Commit { round: 3, participants: 7 }).unwrap();
        drop(j);
        let (_, back2, dropped2) = RoundJournal::open(&path).unwrap();
        assert_eq!(back2.len(), 3);
        assert_eq!(back2[2], Frame::Commit { round: 3, participants: 7 });
        assert_eq!(dropped2, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn prop_truncation_recovers_longest_clean_prefix() {
        let path = tmp("prop_trunc");
        forall("journal truncation", 60, |g: &mut Gen| {
            let frames: Vec<Frame> = (0..g.usize_in(1, 6)).map(|_| gen_frame(g)).collect();
            let mut bytes = Vec::new();
            let mut ends = Vec::new();
            for f in &frames {
                bytes.extend_from_slice(&encode_frame(f));
                ends.push(bytes.len());
            }
            let cut = g.usize_in(0, bytes.len());
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let (_, back, dropped) = RoundJournal::open(&path).unwrap();
            let want = ends.iter().filter(|&&e| e <= cut).count();
            assert_eq!(back[..], frames[..want], "cut at {cut}");
            let clean = ends[..want].last().copied().unwrap_or(0);
            assert_eq!(dropped, (cut - clean) as u64);
        });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn prop_corruption_ends_the_log_at_the_bad_record() {
        // A flipped bit inside record i (past its length prefix) must
        // yield exactly records 0..i — never a silently different record.
        let path = tmp("prop_corrupt");
        forall("journal corruption", 60, |g: &mut Gen| {
            let frames: Vec<Frame> = (0..g.usize_in(2, 6)).map(|_| gen_frame(g)).collect();
            let mut bytes = Vec::new();
            let mut starts = Vec::new();
            for f in &frames {
                starts.push(bytes.len());
                bytes.extend_from_slice(&encode_frame(f));
            }
            let victim = g.usize_in(0, frames.len() - 1);
            let rec_start = starts[victim];
            let rec_end = *starts.get(victim + 1).unwrap_or(&bytes.len());
            let pos = g.usize_in(rec_start + 4, rec_end - 1);
            bytes[pos] ^= 1 << g.usize_in(0, 7);
            std::fs::write(&path, &bytes).unwrap();
            let (_, back, dropped) = RoundJournal::open(&path).unwrap();
            assert_eq!(back[..], frames[..victim], "corrupt byte {pos} in record {victim}");
            assert_eq!(dropped, (bytes.len() - rec_start) as u64);
        });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_raw_validates() {
        let path = tmp("raw");
        let mut j = RoundJournal::create(&path).unwrap();
        let good = encode_frame(&Frame::Drop { round: 2, client: 5 });
        j.append_raw(&good).unwrap();
        assert!(j.append_raw(&good[..good.len() - 1]).is_err(), "partial frame rejected");
        assert!(j.append_raw(b"garbage").is_err(), "garbage rejected");
        let mut two = good.clone();
        two.extend_from_slice(&good);
        assert!(j.append_raw(&two).is_err(), "more than one frame rejected");
        drop(j);
        let (_, back, dropped) = RoundJournal::open(&path).unwrap();
        assert_eq!(back, vec![Frame::Drop { round: 2, client: 5 }]);
        assert_eq!(dropped, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn create_truncates_existing() {
        let path = tmp("create");
        {
            let mut j = RoundJournal::create(&path).unwrap();
            j.append(&Frame::Hello { round: 0, client: 1 }).unwrap();
        }
        let j = RoundJournal::create(&path).unwrap();
        assert_eq!(j.len_bytes(), 0);
        drop(j);
        let (_, back, _) = RoundJournal::open(&path).unwrap();
        assert!(back.is_empty());
        let _ = std::fs::remove_file(&path);
    }
}
