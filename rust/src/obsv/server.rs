//! The scrape endpoint: a minimal, dependency-free HTTP/1.1 text server
//! over `std::net`, plus the matching one-shot client the sims and CI
//! gates use to scrape it.
//!
//! One background thread polls a non-blocking listener and answers one
//! `GET` per connection — `/metrics` (Prometheus text exposition),
//! `/health` (JSON scoreboard) and `/trace` (JSONL tail), all rendered
//! from the shared [`ObsShared`] state the wrapping
//! [`ObsAggregator`](crate::obsv::ObsAggregator) publishes into. The
//! server never touches the aggregation stack itself: everything it can
//! serve has already passed the trace screen or is a metrics/health
//! rollup, so a scrape can race a round freely without observing shares.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::ObsShared;

/// How long the accept loop sleeps between polls. Scrapes are human/CI
/// cadence — single-digit milliseconds of accept latency is invisible.
const ACCEPT_TICK: Duration = Duration::from_millis(2);

/// Per-connection socket budget: a scrape either completes quickly or
/// the connection is dropped — the ops plane must never hold a thread
/// hostage to a stalled client.
const IO_TIMEOUT: Duration = Duration::from_millis(500);

/// Largest request head we accept; a plain `GET /trace?n=100` is < 100
/// bytes, so anything bigger is not a scraper.
const MAX_REQUEST_BYTES: usize = 4096;

/// The live scrape endpoint. Owns its listener thread; dropping the
/// server stops and joins it.
pub struct ObsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `shared`. Returns once the socket is bound, so
    /// [`ObsServer::addr`] is immediately scrape-able.
    pub(crate) fn start(listen: &str, shared: Arc<ObsShared>) -> std::io::Result<ObsServer> {
        let listener = TcpListener::bind(listen)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("cloak-obs".into())
            .spawn(move || serve(listener, shared, stop2))?;
        Ok(ObsServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address — the resolved port when constructed with `:0`.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve(listener: TcpListener, shared: Arc<ObsShared>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // One request per connection; errors only lose that
                // scrape, never the server.
                let _ = handle(stream, &shared);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(ACCEPT_TICK),
            Err(_) => std::thread::sleep(ACCEPT_TICK),
        }
    }
}

fn handle(mut stream: TcpStream, shared: &ObsShared) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let mut head = Vec::new();
    let mut buf = [0u8; 512];
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > MAX_REQUEST_BYTES {
            break;
        }
    }
    let text = String::from_utf8_lossy(&head);
    let mut parts = text.split_whitespace();
    let (method, target) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(&mut stream, "405 Method Not Allowed", "text/plain", "GET only\n");
    }
    let (path, query) = target.split_once('?').unwrap_or((target, ""));
    match path {
        "/" => respond(
            &mut stream,
            "200 OK",
            "text/plain",
            "cloak-agg ops plane: /metrics /health /trace[?n=K]\n",
        ),
        "/metrics" => {
            respond(&mut stream, "200 OK", "text/plain; version=0.0.4", &shared.metrics_text())
        }
        "/health" => respond(&mut stream, "200 OK", "application/json", &shared.health_text()),
        "/trace" => {
            let n = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("n="))
                .and_then(|v| v.parse::<usize>().ok());
            respond(&mut stream, "200 OK", "application/x-ndjson", &shared.trace_text(n))
        }
        _ => respond(&mut stream, "404 Not Found", "text/plain", "unknown path\n"),
    }
}

fn respond(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// One-shot scrape client for the sims, tests and CI gates: `GET path`
/// against `addr`, returning `(status_code, body)`.
pub fn http_get(addr: SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: ops\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let status = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| std::io::Error::new(ErrorKind::InvalidData, "malformed status line"))?;
    let body = match raw.split_once("\r\n\r\n") {
        Some((_, b)) => b.to_string(),
        None => String::new(),
    };
    Ok((status, body))
}
