//! The SLO watchdog: per-round budget rules evaluated over the flight
//! recorder's rollups.
//!
//! The watchdog never looks at protocol data — its whole input is the
//! [`TraceExport`] the [`Tracer`](crate::telemetry::Tracer) already
//! screens down to sizes, timings, ids and outcomes. Each rule compares
//! one public operational quantity from a round against a budget in
//! [`SloPolicy`]; a breached budget becomes a typed [`SloAlert`] (surfaced
//! on `/health`) and a screened [`EventKind::SloBreach`] record (surfaced
//! on `/trace` and in every downstream export). Rounds are evaluated
//! exactly once: the watchdog remembers the newest round id it has judged
//! and re-running `evaluate` over a grown trace only considers rounds
//! past it, so alerts never duplicate across publishes.

use crate::telemetry::{round_reports, EventKind, TraceExport};
use crate::util::json::{num, obj, s, Json};

/// Which SLO rule fired. Each rule carries a fixed numeric id — the
/// `count` payload of the [`EventKind::SloBreach`] record it emits, so a
/// breach survives the numeric-only trace screen without a free-form
/// string field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloKind {
    /// Deadline-missed frames per participant exceeded
    /// [`SloPolicy::max_deadline_miss_rate`].
    DeadlineMissRate,
    /// Work resends per participant exceeded
    /// [`SloPolicy::max_retry_rate`].
    RetryRate,
    /// In-round takeovers exceeded [`SloPolicy::max_takeovers`].
    TakeoverBudget,
    /// Client uplink bytes per participant exceeded
    /// [`SloPolicy::max_bytes_per_user`].
    BytesPerUser,
    /// A journal commit fsync exceeded [`SloPolicy::max_fsync_ns`].
    FsyncLatency,
}

impl SloKind {
    /// Every rule, for exhaustive tests and renderers.
    pub const ALL: [SloKind; 5] = [
        SloKind::DeadlineMissRate,
        SloKind::RetryRate,
        SloKind::TakeoverBudget,
        SloKind::BytesPerUser,
        SloKind::FsyncLatency,
    ];

    /// The fixed wire id carried as the breach event's `count`. Stable
    /// across releases — downstream dashboards key on it.
    pub fn rule_id(self) -> u64 {
        match self {
            SloKind::DeadlineMissRate => 1,
            SloKind::RetryRate => 2,
            SloKind::TakeoverBudget => 3,
            SloKind::BytesPerUser => 4,
            SloKind::FsyncLatency => 5,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SloKind::DeadlineMissRate => "deadline_miss_rate",
            SloKind::RetryRate => "retry_rate",
            SloKind::TakeoverBudget => "takeover_budget",
            SloKind::BytesPerUser => "bytes_per_user",
            SloKind::FsyncLatency => "fsync_latency",
        }
    }

    pub fn from_rule_id(id: u64) -> Option<SloKind> {
        SloKind::ALL.into_iter().find(|k| k.rule_id() == id)
    }
}

/// Per-round SLO budgets. The default is "never fires" — every budget at
/// its neutral maximum — so wiring the ops plane into a stack changes
/// nothing until a deployer opts into limits.
#[derive(Clone, Copy, Debug)]
pub struct SloPolicy {
    /// Budget for deadline-missed frames per participant
    /// ([`EventKind::Deadline`] counts over the round's admissions).
    pub max_deadline_miss_rate: f64,
    /// Budget for work resends per participant.
    pub max_retry_rate: f64,
    /// Budget for in-round lost-range takeovers.
    pub max_takeovers: u64,
    /// Budget for client uplink bytes per participant — typically seeded
    /// from a committed bench baseline via
    /// [`SloPolicy::bytes_budget_from_bench`] plus slack.
    pub max_bytes_per_user: f64,
    /// Budget for a single journal commit's fsync wall, in nanoseconds.
    pub max_fsync_ns: u64,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            max_deadline_miss_rate: f64::INFINITY,
            max_retry_rate: f64::INFINITY,
            max_takeovers: u64::MAX,
            max_bytes_per_user: f64::INFINITY,
            max_fsync_ns: u64::MAX,
        }
    }
}

impl SloPolicy {
    /// Pull a bytes-per-user baseline out of a committed benchkit report
    /// (`BENCH_*.json`): the largest numeric `bytes_per_user` field found
    /// anywhere in the document, or `None` when the report carries none.
    /// Callers typically multiply by a slack factor before budgeting.
    pub fn bytes_budget_from_bench(report: &Json) -> Option<f64> {
        fn scan(j: &Json, best: &mut Option<f64>) {
            match j {
                Json::Obj(m) => {
                    for (k, v) in m {
                        if k == "bytes_per_user" {
                            if let Some(x) = v.as_f64() {
                                *best = Some(best.map_or(x, |b: f64| b.max(x)));
                            }
                        }
                        scan(v, best);
                    }
                }
                Json::Arr(a) => a.iter().for_each(|v| scan(v, best)),
                _ => {}
            }
        }
        let mut best = None;
        scan(report, &mut best);
        best
    }
}

/// One breached budget: which rule, on which round, observed vs budget.
/// Everything here is a public operational quantity — rates, counts and
/// latencies only.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloAlert {
    pub kind: SloKind,
    pub round: u64,
    pub observed: f64,
    pub budget: f64,
}

impl SloAlert {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("rule", s(self.kind.as_str())),
            ("rule_id", num(self.kind.rule_id() as f64)),
            ("round", num(self.round as f64)),
            ("observed", num(self.observed)),
            ("budget", num(self.budget)),
        ])
    }
}

/// Per-round aggregates the watchdog needs that [`round_reports`] does
/// not carry: deadline misses, ingestion rejects, and the slowest commit
/// fsync of the round.
#[derive(Clone, Copy, Debug, Default)]
struct RoundExtras {
    deadline_misses: u64,
    rejects: u64,
    max_fsync_ns: u64,
}

/// Evaluates [`SloPolicy`] rules over every newly completed round in a
/// trace, accumulating [`SloAlert`]s. Stateful so the same recorder can
/// be re-snapshotted after each round without re-alerting old rounds.
pub struct Watchdog {
    policy: SloPolicy,
    /// Newest round id already judged; rounds at or below it are skipped.
    evaluated_through: Option<u64>,
    alerts: Vec<SloAlert>,
}

impl Watchdog {
    pub fn new(policy: SloPolicy) -> Self {
        Watchdog { policy, evaluated_through: None, alerts: Vec::new() }
    }

    pub fn policy(&self) -> &SloPolicy {
        &self.policy
    }

    /// Every alert raised so far, oldest first.
    pub fn alerts(&self) -> &[SloAlert] {
        &self.alerts
    }

    /// Judge every round in `export` newer than the last call's newest,
    /// returning only the alerts raised by THIS call (the full history
    /// stays on [`Watchdog::alerts`]).
    pub fn evaluate(&mut self, export: &TraceExport) -> Vec<SloAlert> {
        use std::collections::BTreeMap;
        let mut extras: BTreeMap<u64, RoundExtras> = BTreeMap::new();
        for e in &export.events {
            let x = extras.entry(e.round).or_default();
            match e.kind {
                EventKind::Deadline => x.deadline_misses += e.count.max(1),
                EventKind::Reject => x.rejects += e.count.max(1),
                EventKind::JournalCommit => x.max_fsync_ns = x.max_fsync_ns.max(e.value as u64),
                _ => {}
            }
        }
        let mut fresh = Vec::new();
        for r in round_reports(export) {
            if self.evaluated_through.is_some_and(|t| r.round <= t) {
                continue;
            }
            self.evaluated_through = Some(r.round);
            let x = extras.get(&r.round).copied().unwrap_or_default();
            // Rates denominate over streaming admissions; a round with no
            // admissions (full-cohort simulation path) denominates over 1
            // so absolute counts still gate.
            let per = r.participants.max(1) as f64;
            let p = &self.policy;
            let mut raise = |kind: SloKind, observed: f64, budget: f64| {
                fresh.push(SloAlert { kind, round: r.round, observed, budget });
            };
            let miss_rate = x.deadline_misses as f64 / per;
            if miss_rate > p.max_deadline_miss_rate {
                raise(SloKind::DeadlineMissRate, miss_rate, p.max_deadline_miss_rate);
            }
            let retry_rate = r.retries as f64 / per;
            if retry_rate > p.max_retry_rate {
                raise(SloKind::RetryRate, retry_rate, p.max_retry_rate);
            }
            if r.takeovers > p.max_takeovers {
                raise(SloKind::TakeoverBudget, r.takeovers as f64, p.max_takeovers as f64);
            }
            if r.participants > 0 {
                let bpu = r.bytes_up as f64 / r.participants as f64;
                if bpu > p.max_bytes_per_user {
                    raise(SloKind::BytesPerUser, bpu, p.max_bytes_per_user);
                }
            }
            if x.max_fsync_ns > p.max_fsync_ns {
                raise(SloKind::FsyncLatency, x.max_fsync_ns as f64, p.max_fsync_ns as f64);
            }
        }
        self.alerts.extend_from_slice(&fresh);
        fresh
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{EventRecord, SpanKind, SpanRecord};

    fn round_span(round: u64, wall_ns: u64) -> SpanRecord {
        SpanRecord {
            id: round + 1,
            kind: SpanKind::Round,
            name: "round",
            round,
            shard: u32::MAX,
            start_ns: 0,
            end_ns: wall_ns,
            replay: false,
        }
    }

    fn lossy_round(round: u64) -> Vec<EventRecord> {
        vec![
            EventRecord::new(EventKind::Admit, round).with_count(10),
            EventRecord::new(EventKind::ClientUplink, round).with_bytes(4_000).with_count(10),
            EventRecord::new(EventKind::Retry, round).with_count(3),
            EventRecord::new(EventKind::Deadline, round).with_count(5),
            EventRecord::new(EventKind::Takeover, round).with_count(1),
            EventRecord::new(EventKind::JournalCommit, round).with_bytes(64).with_value(9e6),
        ]
    }

    fn export(rounds: &[u64]) -> TraceExport {
        TraceExport {
            spans: rounds.iter().map(|&r| round_span(r, 1_000)).collect(),
            events: rounds.iter().flat_map(|&r| lossy_round(r)).collect(),
            dropped_spans: 0,
            dropped_events: 0,
            open_spans: 0,
        }
    }

    #[test]
    fn default_policy_never_fires() {
        let mut w = Watchdog::new(SloPolicy::default());
        assert!(w.evaluate(&export(&[0, 1, 2])).is_empty());
        assert!(w.alerts().is_empty());
    }

    #[test]
    fn every_rule_fires_with_the_right_id_and_magnitudes() {
        let mut w = Watchdog::new(SloPolicy {
            max_deadline_miss_rate: 0.25, // observed 5/10 = 0.5
            max_retry_rate: 0.1,          // observed 3/10 = 0.3
            max_takeovers: 0,             // observed 1
            max_bytes_per_user: 300.0,    // observed 400
            max_fsync_ns: 1_000_000,      // observed 9e6
        });
        let fresh = w.evaluate(&export(&[4]));
        assert_eq!(fresh.len(), SloKind::ALL.len(), "{fresh:?}");
        for (alert, kind) in fresh.iter().zip(SloKind::ALL) {
            assert_eq!(alert.kind, kind);
            assert_eq!(alert.round, 4);
            assert!(alert.observed > alert.budget, "{alert:?}");
            assert_eq!(SloKind::from_rule_id(alert.kind.rule_id()), Some(kind));
        }
        let seen: Vec<f64> = fresh.iter().map(|a| a.observed).collect();
        assert_eq!(seen, vec![0.5, 0.3, 1.0, 400.0, 9e6]);
    }

    #[test]
    fn rounds_are_judged_exactly_once_across_growing_snapshots() {
        let mut w = Watchdog::new(SloPolicy { max_takeovers: 0, ..SloPolicy::default() });
        assert_eq!(w.evaluate(&export(&[0])).len(), 1);
        // Re-publishing the same trace raises nothing new…
        assert_eq!(w.evaluate(&export(&[0])).len(), 0);
        // …and a grown trace only judges the new round.
        let fresh = w.evaluate(&export(&[0, 1]));
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].round, 1);
        assert_eq!(w.alerts().len(), 2, "history accumulates");
    }

    #[test]
    fn bytes_budget_reads_a_bench_baseline() {
        let report = Json::parse(
            r#"{"group":"g","cases":[{"name":"a","extras":{"bytes_per_user":512}},
                {"name":"b","extras":{"bytes_per_user":768}}]}"#,
        )
        .unwrap();
        assert_eq!(SloPolicy::bytes_budget_from_bench(&report), Some(768.0));
        assert_eq!(
            SloPolicy::bytes_budget_from_bench(&Json::parse("{}").unwrap()),
            None
        );
    }

    #[test]
    fn alert_json_is_numeric_plus_fixed_rule_label() {
        let a = SloAlert { kind: SloKind::BytesPerUser, round: 7, observed: 9.5, budget: 8.0 };
        let j = a.to_json();
        assert_eq!(j.get("rule").and_then(Json::as_str), Some("bytes_per_user"));
        assert_eq!(j.get("rule_id").and_then(Json::as_u64), Some(4));
        assert_eq!(j.get("round").and_then(Json::as_u64), Some(7));
    }
}
