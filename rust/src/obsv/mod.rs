//! The live ops plane: a scrape endpoint, streaming trace subscribers
//! and an SLO watchdog over the flight recorder.
//!
//! [`crate::telemetry`] gave every stack a flight recorder you can
//! snapshot *after* the fact; this module makes the same signals
//! observable *while* rounds run, without adding a single dependency or
//! touching the protocol hot path:
//!
//! ```text
//!   aggregation stack (local / loopback / tcp / elastic)
//!        │ spans + events             │ counters + histograms
//!        ▼                            ▼
//!   Tracer ──subscribe()──► TraceSubscriber      metrics::Registry
//!        │                       │ (bounded, drop-oldest)  │ (Arc-shared)
//!        │ snapshot()            ▼                         │
//!        ▼                  trace tail ◄─── drain ───┐     │
//!   Watchdog (SloPolicy) ──► SloAlerts               │     │
//!        │                       │                   │     │
//!        ▼                       ▼                   ▼     ▼
//!   ObsAggregator::publish ──► ObsShared ◄─────── ObsServer thread
//!                                              GET /metrics /health /trace
//! ```
//!
//! [`ObsAggregator`] decorates any [`Aggregator`]: it installs (or
//! adopts) the stack's [`Tracer`], attaches a bounded [`TraceSubscriber`]
//! and, after every round, publishes — drains the subscriber into the
//! `/trace` tail, re-renders the `/health` scoreboard, mirrors trace
//! rollups into monotone registry counters, and runs the [`Watchdog`]'s
//! per-round SLO rules. [`ObsServer`] is a one-thread `std::net` HTTP
//! responder over that shared state; [`http_get`] is the matching
//! one-shot scrape client the sims and CI gates use.
//!
//! # Trust model
//!
//! The ops plane widens *reachability*, not the privacy boundary — a
//! scraper on the ops port learns strictly less than the coordinator
//! operator already could:
//!
//! * `/trace` serves exactly the lines the telemetry layer's fixed
//!   registry already screens: static span names, enum event kinds, and
//!   numeric payloads (sizes, timings, ids, outcomes). Shares, pool
//!   contents and seeds are unrepresentable in that schema, so the live
//!   tap cannot leak what the ring could not store. Subscribers are
//!   bounded and drop-oldest; a slow scraper loses history (counted in
//!   `dropped_records`), never blocks a round.
//! * `/metrics` renders [`Registry`] counters and histogram quantiles —
//!   operational aggregates by construction.
//! * `/health` is liveness, EWMA latency, failure/takeover counts,
//!   journal commit lag and SLO alerts — all public operational
//!   quantities (rates, counts, latencies).
//! * The endpoint is **opt-in** ([`AggregatorBuilder::ops_listen`]) and
//!   binds wherever the deployer points it; like the coordinator↔shard
//!   links, anything beyond loopback needs transport encryption and
//!   authentication from the deployment (out of scope here, flagged in
//!   [`crate::cluster`]'s trust notes).
//!
//! [`AggregatorBuilder::ops_listen`]: crate::aggregator::AggregatorBuilder::ops_listen

#![deny(clippy::redundant_clone)]

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};

use crate::aggregator::{Aggregator, AggregatorError};
use crate::engine::{ClientSeeds, ClientView, EngineConfig, RoundInput, RoundResult, ShardHealth};
use crate::metrics::Registry;
use crate::telemetry::{
    attributed_bytes, EventKind, EventRecord, TraceExport, TraceSubscriber, Tracer,
    DEFAULT_CAPACITY,
};
use crate::util::json::{num, obj, s, Json};

mod server;
mod watchdog;

pub use server::{http_get, ObsServer};
pub use watchdog::{SloAlert, SloKind, SloPolicy, Watchdog};

/// Bound on both the `/trace` tail and the live subscriber queue. At
/// ~120 bytes a line this caps the ops plane's memory near half a
/// megabyte while holding several rounds of a busy cluster trace.
pub const TAIL_CAPACITY: usize = 4096;

/// What the server thread and the publishing aggregator share. Every
/// field is independently locked; no lock is ever held across I/O or a
/// round.
pub(crate) struct ObsShared {
    registry: Registry,
    /// Replaced wholesale when `set_telemetry` installs a new recorder.
    sub: Mutex<TraceSubscriber>,
    tail: Mutex<VecDeque<String>>,
    /// Last-published `/health` document (JSON text).
    health: Mutex<String>,
}

impl ObsShared {
    /// Move every line the subscriber buffered into the bounded tail.
    fn drain_tail(&self) {
        let lines = crate::util::sync::lock(&self.sub).drain();
        if lines.is_empty() {
            return;
        }
        let mut tail = crate::util::sync::lock(&self.tail);
        for line in lines {
            if tail.len() == TAIL_CAPACITY {
                tail.pop_front();
            }
            tail.push_back(line);
        }
    }

    /// The `/trace` body: the last `last` tail lines (all when `None`),
    /// pulled fresh from the subscriber so a mid-round scrape sees
    /// records the recorder emitted moments ago.
    pub(crate) fn trace_text(&self, last: Option<usize>) -> String {
        self.drain_tail();
        let tail = crate::util::sync::lock(&self.tail);
        let skip = last.map_or(0, |n| tail.len().saturating_sub(n));
        let mut out = String::new();
        for line in tail.iter().skip(skip) {
            out.push_str(line);
            out.push('\n');
        }
        out
    }

    /// The `/metrics` body: the registry in Prometheus text exposition,
    /// plus the live subscriber drop counter.
    pub(crate) fn metrics_text(&self) -> String {
        let mut out = prometheus_text(&self.registry);
        let dropped = crate::util::sync::lock(&self.sub).dropped_records();
        out.push_str("# TYPE cloak_obsv_subscriber_dropped_records counter\n");
        let _ = writeln!(out, "cloak_obsv_subscriber_dropped_records {dropped}");
        out
    }

    pub(crate) fn health_text(&self) -> String {
        crate::util::sync::lock(&self.health).clone()
    }
}

/// Map a dotted registry name onto the Prometheus charset.
fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

/// Render a [`Registry`] as Prometheus text exposition: counters
/// verbatim, histograms as a `_count` counter plus a quantile summary
/// (p50/p95/p99 upper bounds) and a `_mean_ns` gauge. Histograms with no
/// samples export only their zero `_count` — typed-empty, never a fake
/// zero latency.
pub fn prometheus_text(registry: &Registry) -> String {
    let mut out = String::new();
    for (name, v) in registry.counters_snapshot() {
        let n = sanitize(&name);
        let _ = writeln!(out, "# TYPE cloak_{n} counter");
        let _ = writeln!(out, "cloak_{n} {v}");
    }
    for (name, h) in registry.histograms_snapshot() {
        let n = sanitize(&name);
        let _ = writeln!(out, "# TYPE cloak_{n}_count counter");
        let _ = writeln!(out, "cloak_{n}_count {}", h.count);
        if let Some(q) = h.quantiles {
            let _ = writeln!(out, "# TYPE cloak_{n}_ns summary");
            let _ = writeln!(out, "cloak_{n}_ns{{quantile=\"0.5\"}} {}", q.p50_ns);
            let _ = writeln!(out, "cloak_{n}_ns{{quantile=\"0.95\"}} {}", q.p95_ns);
            let _ = writeln!(out, "cloak_{n}_ns{{quantile=\"0.99\"}} {}", q.p99_ns);
            let _ = writeln!(out, "# TYPE cloak_{n}_mean_ns gauge");
            let _ = writeln!(out, "cloak_{n}_mean_ns {}", h.mean_ns);
        }
    }
    out
}

/// The ops-plane decorator: any [`Aggregator`] plus a scrape endpoint, a
/// live trace tail and the SLO watchdog. Built by
/// [`AggregatorBuilder::ops_listen`] — frontends keep holding a plain
/// `Box<dyn Aggregator>` and discover the plane via
/// [`Aggregator::ops_addr`].
///
/// [`AggregatorBuilder::ops_listen`]: crate::aggregator::AggregatorBuilder::ops_listen
pub struct ObsAggregator {
    inner: Box<dyn Aggregator>,
    shared: Arc<ObsShared>,
    server: ObsServer,
    watchdog: Watchdog,
    tracer: Tracer,
    /// Publish baselines for the monotone counter mirrors (registry
    /// counters only add; trace rollups are absolute).
    published_attributed: u64,
    published_dropped: u64,
}

impl ObsAggregator {
    /// Wrap `inner`, binding the scrape endpoint on `listen` (use
    /// `"127.0.0.1:0"` for an ephemeral port). Adopts the stack's
    /// existing enabled [`Tracer`], or installs a fresh one at
    /// [`DEFAULT_CAPACITY`] — the ops plane is useless over a noop
    /// recorder.
    pub fn wrap(
        mut inner: Box<dyn Aggregator>,
        listen: &str,
        policy: SloPolicy,
    ) -> std::io::Result<ObsAggregator> {
        let tracer = {
            let t = inner.telemetry();
            if t.is_enabled() {
                t
            } else {
                let t = Tracer::new(DEFAULT_CAPACITY);
                inner.set_telemetry(t.clone());
                t
            }
        };
        let sub = tracer.subscribe(TAIL_CAPACITY);
        let shared = Arc::new(ObsShared {
            registry: inner.metrics().clone(),
            sub: Mutex::new(sub),
            tail: Mutex::new(VecDeque::new()),
            health: Mutex::new(String::new()),
        });
        let server = ObsServer::start(listen, Arc::clone(&shared))?;
        let mut me = ObsAggregator {
            inner,
            shared,
            server,
            watchdog: Watchdog::new(policy),
            tracer,
            published_attributed: 0,
            published_dropped: 0,
        };
        // Seed /health so a scrape before the first round sees a
        // well-formed board instead of an empty body.
        me.publish();
        Ok(me)
    }

    /// The bound scrape address.
    pub fn addr(&self) -> SocketAddr {
        self.server.addr()
    }

    /// Alerts raised so far (also on `/health` and, as
    /// [`EventKind::SloBreach`] records, on `/trace`).
    pub fn alerts(&self) -> &[SloAlert] {
        self.watchdog.alerts()
    }

    /// One publish cycle: judge new rounds, mirror trace rollups into
    /// counters, refresh the tail and the health board. Runs after every
    /// round (success or failure — breaches matter most on bad rounds).
    fn publish(&mut self) {
        let snap = self.tracer.snapshot();
        let fresh = self.watchdog.evaluate(&snap);
        for a in &fresh {
            // The breach record is numeric-only by construction: the rule
            // travels as its fixed id, the magnitude as `value`.
            self.tracer.record(
                EventRecord::new(EventKind::SloBreach, a.round)
                    .with_count(a.kind.rule_id())
                    .with_value(a.observed),
            );
        }
        if !fresh.is_empty() {
            self.inner.metrics().counter("obsv.slo.breaches").add(fresh.len() as u64);
        }
        let attributed = attributed_bytes(&snap.events);
        self.inner
            .metrics()
            .counter("obsv.trace.attributed_bytes")
            .add(attributed.saturating_sub(self.published_attributed));
        self.published_attributed = self.published_attributed.max(attributed);
        let dropped = self.tracer.subscriber_dropped_records();
        self.inner
            .metrics()
            .counter("obsv.trace.dropped_records")
            .add(dropped.saturating_sub(self.published_dropped));
        self.published_dropped = self.published_dropped.max(dropped);
        self.inner.metrics().counter("obsv.publish.count").inc();
        self.shared.drain_tail();
        let health = self.render_health(&snap);
        *crate::util::sync::lock(&self.shared.health) = health;
    }

    /// The `/health` document: stack identity, per-shard scoreboard,
    /// journal commit lag, and the alert history.
    fn render_health(&self, snap: &TraceExport) -> String {
        let health = self.inner.shard_health();
        let shards: Vec<Json> = health
            .iter()
            .enumerate()
            .map(|(i, h)| {
                obj(vec![
                    ("shard", num(i as f64)),
                    ("alive", Json::Bool(h.alive)),
                    ("latency_ewma_s", num(h.latency_ewma_s)),
                    ("consecutive_failures", num(f64::from(h.consecutive_failures))),
                    ("failures", num(h.failures as f64)),
                    ("rounds_ok", num(h.rounds_ok as f64)),
                    ("takeovers_absorbed", num(h.takeovers_absorbed as f64)),
                ])
            })
            .collect();
        let mut commits = 0u64;
        let mut last_commit_round = 0u64;
        let mut last_fsync_ns = 0u64;
        for e in &snap.events {
            if e.kind == EventKind::JournalCommit && !e.replay {
                commits += 1;
                if e.round >= last_commit_round {
                    last_commit_round = e.round;
                    last_fsync_ns = e.value as u64;
                }
            }
        }
        let rounds_run = self.inner.rounds_run();
        // Rounds finished but not yet committed; 0 on journal-less
        // stacks (nothing is behind when nothing is durable).
        let commit_lag = if commits > 0 {
            rounds_run.saturating_sub(last_commit_round + 1)
        } else {
            0
        };
        let journal = obj(vec![
            ("commits", num(commits as f64)),
            ("last_commit_round", num(last_commit_round as f64)),
            ("commit_lag_rounds", num(commit_lag as f64)),
            ("last_fsync_ns", num(last_fsync_ns as f64)),
        ]);
        let alerts: Vec<Json> = self.watchdog.alerts().iter().map(SloAlert::to_json).collect();
        let ok = alerts.is_empty() && health.iter().all(|h| h.alive);
        let mut text = obj(vec![
            ("ok", Json::Bool(ok)),
            ("backend", s(self.inner.backend_label())),
            ("rounds_run", num(rounds_run as f64)),
            ("next_round", num(self.inner.next_round() as f64)),
            ("shards", num(self.inner.shards() as f64)),
            ("retries", num(self.inner.shard_retries() as f64)),
            ("takeovers", num(self.inner.shard_takeovers() as f64)),
            ("shard_health", Json::Arr(shards)),
            ("journal", journal),
            ("alerts", Json::Arr(alerts)),
        ])
        .to_string_pretty();
        text.push('\n');
        text
    }
}

impl Aggregator for ObsAggregator {
    fn config(&self) -> &EngineConfig {
        self.inner.config()
    }

    fn next_round(&self) -> u64 {
        self.inner.next_round()
    }

    fn rounds_run(&self) -> u64 {
        self.inner.rounds_run()
    }

    fn shards(&self) -> usize {
        self.inner.shards()
    }

    fn metrics(&self) -> &Registry {
        self.inner.metrics()
    }

    fn backend_label(&self) -> &'static str {
        self.inner.backend_label()
    }

    fn encode_client_shares(
        &self,
        round: u64,
        client: u32,
        inputs: &RoundInput<'_>,
        seeds: &dyn ClientSeeds,
    ) -> Result<Vec<u64>, AggregatorError> {
        self.inner.encode_client_shares(round, client, inputs, seeds)
    }

    fn run_round(
        &mut self,
        inputs: &RoundInput<'_>,
        seeds: &dyn ClientSeeds,
    ) -> Result<RoundResult, AggregatorError> {
        let r = self.inner.run_round(inputs, seeds);
        self.publish();
        r
    }

    fn run_round_with_views(
        &mut self,
        inputs: &RoundInput<'_>,
        seeds: &dyn ClientSeeds,
    ) -> Result<(RoundResult, Vec<ClientView>), AggregatorError> {
        let r = self.inner.run_round_with_views(inputs, seeds);
        self.publish();
        r
    }

    fn run_round_streaming(
        &mut self,
        pools: &[Vec<u64>],
        participants: usize,
    ) -> Result<RoundResult, AggregatorError> {
        let r = self.inner.run_round_streaming(pools, participants);
        self.publish();
        r
    }

    fn run_round_streaming_flat(
        &mut self,
        flat: &[u64],
        participants: usize,
    ) -> Result<RoundResult, AggregatorError> {
        let r = self.inner.run_round_streaming_flat(flat, participants);
        self.publish();
        r
    }

    fn fast_forward(&mut self, next_round: u64) -> Result<(), AggregatorError> {
        let r = self.inner.fast_forward(next_round);
        self.publish();
        r
    }

    fn shard_retries(&self) -> u64 {
        self.inner.shard_retries()
    }

    fn shard_takeovers(&self) -> u64 {
        self.inner.shard_takeovers()
    }

    fn shard_health(&self) -> Vec<ShardHealth> {
        self.inner.shard_health()
    }

    fn telemetry(&self) -> Tracer {
        self.tracer.clone()
    }

    fn set_telemetry(&mut self, tracer: Tracer) {
        self.inner.set_telemetry(tracer.clone());
        *crate::util::sync::lock(&self.shared.sub) =
            tracer.subscribe(TAIL_CAPACITY);
        self.tracer = tracer;
        // The new recorder's rollups restart from zero; so do the
        // baselines, keeping the counter mirrors monotone.
        self.published_attributed = 0;
        self.published_dropped = 0;
    }

    fn ops_addr(&self) -> Option<SocketAddr> {
        Some(self.server.addr())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregator::AggregatorBuilder;
    use crate::engine::{DerivedClientSeeds, EngineConfig, RoundInput};
    use crate::params::ProtocolPlan;
    use crate::telemetry::SpanKind;

    fn small_cfg(n: usize, d: usize) -> EngineConfig {
        EngineConfig::new(ProtocolPlan::exact_secure_agg(n, 100, 8), d).with_shards(2)
    }

    fn inputs_for(n: usize, d: usize) -> Vec<Vec<f64>> {
        (0..n)
            .map(|i| (0..d).map(|j| ((i * 7 + j * 13) % 100) as f64 / 100.0).collect())
            .collect()
    }

    #[test]
    fn prometheus_rendering_sanitizes_and_types_every_family() {
        let r = Registry::new();
        r.counter("cluster.reconcile.delta_bytes").add(3);
        r.histogram("round.wall").record_ns(100);
        r.histogram("round.empty"); // registered, never sampled
        let text = prometheus_text(&r);
        assert!(text.contains("# TYPE cloak_cluster_reconcile_delta_bytes counter\n"));
        assert!(text.contains("cloak_cluster_reconcile_delta_bytes 3\n"));
        assert!(text.contains("cloak_round_wall_count 1\n"));
        assert!(text.contains("cloak_round_wall_ns{quantile=\"0.5\"} 128\n"));
        assert!(text.contains("cloak_round_wall_ns{quantile=\"0.99\"} 128\n"));
        // Typed-empty: the unsampled histogram exports its zero count and
        // no quantile lines at all.
        assert!(text.contains("cloak_round_empty_count 0\n"));
        assert!(!text.contains("cloak_round_empty_ns{"), "{text}");
        assert!(!text.contains('.'), "metric names must be sanitized");
    }

    #[test]
    fn server_serves_all_three_endpoints_and_404s_the_rest() {
        let tracer = Tracer::new(64);
        let sub = tracer.subscribe(TAIL_CAPACITY);
        let registry = Registry::new();
        registry.counter("obsv.test").add(7);
        let shared = Arc::new(ObsShared {
            registry,
            sub: Mutex::new(sub),
            tail: Mutex::new(VecDeque::new()),
            health: Mutex::new("{\"ok\": true}\n".to_string()),
        });
        let server = ObsServer::start("127.0.0.1:0", Arc::clone(&shared)).unwrap();
        for round in 0..3 {
            tracer.record(EventRecord::new(EventKind::Retry, round).with_count(1));
        }
        let (code, body) = http_get(server.addr(), "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("cloak_obsv_test 7\n"), "{body}");
        assert!(body.contains("cloak_obsv_subscriber_dropped_records 0\n"));
        let (code, body) = http_get(server.addr(), "/health").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "{\"ok\": true}\n");
        // The tail is pulled live — records made after start are served,
        // and ?n= trims to the newest.
        let (code, body) = http_get(server.addr(), "/trace?n=2").unwrap();
        assert_eq!(code, 200);
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 2, "{body}");
        let parsed = TraceExport::parse_jsonl(&body).unwrap();
        assert_eq!(parsed.events.len(), 2);
        assert_eq!(parsed.events[0].round, 1, "oldest of the kept two");
        let (code, _) = http_get(server.addr(), "/shares").unwrap();
        assert_eq!(code, 404);
    }

    #[test]
    fn wrapped_stack_publishes_after_rounds_and_keeps_bit_identity() {
        let (n, d, seed) = (8usize, 4usize, 5u64);
        let inputs = inputs_for(n, d);
        let seeds = DerivedClientSeeds::new(seed);
        let mut plain =
            AggregatorBuilder::new(small_cfg(n, d), seed).loopback().build().unwrap();
        let want = plain.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
        let mut agg = AggregatorBuilder::new(small_cfg(n, d), seed)
            .loopback()
            .ops_listen("127.0.0.1:0")
            .build()
            .unwrap();
        let addr = agg.ops_addr().expect("ops plane must expose its address");
        let got = agg.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
        assert_eq!(got.estimates, want.estimates, "the ops plane must not perturb rounds");
        let (code, metrics) = http_get(addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(metrics.contains("cloak_obsv_publish_count 2\n"), "wrap + round\n{metrics}");
        assert!(metrics.contains("cloak_obsv_trace_attributed_bytes "));
        let (code, health) = http_get(addr, "/health").unwrap();
        assert_eq!(code, 200);
        let h = Json::parse(&health).unwrap();
        assert_eq!(h.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(h.get("backend").and_then(Json::as_str), Some("loopback"));
        assert_eq!(h.get("rounds_run").and_then(Json::as_u64), Some(1));
        let (code, trace) = http_get(addr, "/trace").unwrap();
        assert_eq!(code, 200);
        let parsed = TraceExport::parse_jsonl(&trace).unwrap();
        assert!(parsed.spans.iter().any(|sp| sp.kind == SpanKind::Round));
        assert!(parsed.events.iter().any(|e| e.kind == EventKind::FrameSent));
    }

    #[test]
    fn breach_reaches_health_board_and_trace_tail() {
        let (n, d, seed) = (6usize, 3usize, 3u64);
        let inputs = inputs_for(n, d);
        let seeds = DerivedClientSeeds::new(seed);
        let mut agg = AggregatorBuilder::new(small_cfg(n, d), seed)
            .loopback()
            .ops_listen("127.0.0.1:0")
            .ops_policy(SloPolicy { max_deadline_miss_rate: 0.0, ..SloPolicy::default() })
            .build()
            .unwrap();
        // Simulate deadline misses on the round about to run; the round's
        // spans give the watchdog a round to judge them under.
        agg.telemetry().record(
            EventRecord::new(EventKind::Deadline, agg.next_round()).with_count(3),
        );
        agg.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
        let addr = agg.ops_addr().unwrap();
        let (_, health) = http_get(addr, "/health").unwrap();
        let h = Json::parse(&health).unwrap();
        assert_eq!(h.get("ok"), Some(&Json::Bool(false)), "{health}");
        let alerts = match h.get("alerts") {
            Some(Json::Arr(a)) => a.clone(),
            other => panic!("alerts missing: {other:?}"),
        };
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].get("rule").and_then(Json::as_str), Some("deadline_miss_rate"));
        let (_, trace) = http_get(addr, "/trace").unwrap();
        assert!(trace.contains("\"kind\":\"slo_breach\""), "{trace}");
        let parsed = TraceExport::parse_jsonl(&trace).unwrap();
        let breach = parsed.events.iter().find(|e| e.kind == EventKind::SloBreach).unwrap();
        assert_eq!(breach.count, SloKind::DeadlineMissRate.rule_id());
        let (_, metrics) = http_get(addr, "/metrics").unwrap();
        assert!(metrics.contains("cloak_obsv_slo_breaches 1\n"), "{metrics}");
    }
}
