//! Single-shot aggregation pipeline — the library's simplest entry point.
//!
//! Wires Algorithm 1 (+ the §2.4 pre-randomizer when the plan is a
//! Theorem 1 plan), the shuffler and Algorithm 2 into one call:
//!
//! ```
//! use cloak_agg::prelude::*;
//! let plan = ProtocolPlan::theorem2(50, 1.0, 1e-6).unwrap();
//! let mut p = Pipeline::new(plan, 7);
//! let xs = vec![0.5; 50];
//! let est = p.aggregate(&xs).unwrap();
//! assert!((est - 25.0).abs() <= 50.0 / 500.0); // n/k rounding only
//! ```
//!
//! The full streaming system (many aggregation instances, batching,
//! backpressure, PJRT execution) lives in [`crate::coordinator`]; this type
//! is the reference implementation the integration tests compare it to.

use crate::analyzer::Analyzer;
use crate::encoder::prerandomizer::PreRandomizer;
use crate::encoder::CloakEncoder;
use crate::params::{NeighborNotion, ProtocolPlan};
use crate::rng::{derive_seed, ChaCha20Rng};
use crate::shuffler::{FisherYates, Shuffler};
use crate::transport::{CostModel, Envelope, TrafficStats};

/// One-shot scalar aggregation under a [`ProtocolPlan`].
pub struct Pipeline {
    plan: ProtocolPlan,
    encoder: CloakEncoder,
    prerandomizer: PreRandomizer,
    analyzer: Analyzer,
    seed: u64,
    rounds_run: u64,
    /// Communication accounting for the last round.
    pub last_traffic: TrafficStats,
}

/// Pipeline failure modes.
#[derive(Debug, thiserror::Error)]
pub enum PipelineError {
    #[error("expected {expected} inputs (plan n), got {got}")]
    WrongInputCount { expected: usize, got: usize },
}

impl Pipeline {
    pub fn new(plan: ProtocolPlan, seed: u64) -> Self {
        let encoder = CloakEncoder::new(plan.modulus, plan.scale, plan.num_messages);
        let prerandomizer = match plan.notion {
            NeighborNotion::SingleUser => {
                PreRandomizer::new(plan.modulus, plan.noise_p, plan.noise_q)
            }
            NeighborNotion::SumPreserving => PreRandomizer::disabled(plan.modulus),
        };
        let analyzer = Analyzer::new(plan.modulus, plan.scale, plan.n);
        Pipeline {
            plan,
            encoder,
            prerandomizer,
            analyzer,
            seed,
            rounds_run: 0,
            last_traffic: TrafficStats::default(),
        }
    }

    pub fn plan(&self) -> &ProtocolPlan {
        &self.plan
    }

    /// Run one aggregation round over `xs` (one value in [0,1] per user).
    /// Returns the analyzer's estimate of Σ x_i.
    pub fn aggregate(&mut self, xs: &[f64]) -> Result<f64, PipelineError> {
        if xs.len() != self.plan.n {
            return Err(PipelineError::WrongInputCount { expected: self.plan.n, got: xs.len() });
        }
        let m = self.plan.num_messages;
        let round = self.rounds_run;
        self.rounds_run += 1;

        // --- user side: pre-randomize + encode -------------------------
        let mut messages: Vec<u64> = vec![0; xs.len() * m];
        let mut traffic = TrafficStats::default();
        let cost = CostModel::default();
        let bytes = Envelope::wire_bytes(self.plan.message_bits());
        for (i, &x) in xs.iter().enumerate() {
            // Every user gets an independent ChaCha stream derived from the
            // pipeline seed — the same seed-splitting protocol the
            // coordinator and the Pallas cross-check use.
            let mut rng =
                ChaCha20Rng::from_seed_and_stream(derive_seed(self.seed, round), i as u64);
            let xbar = self.encoder.codec().encode(x);
            let (noised, _w) = self.prerandomizer.apply(xbar, &mut rng);
            self.encoder
                .encode_quantized_into(noised, &mut rng, &mut messages[i * m..(i + 1) * m]);
            traffic.record_batch(m, bytes, &cost);
        }

        // --- shuffler ---------------------------------------------------
        let mut fy = FisherYates::new(ChaCha20Rng::from_seed_and_stream(
            derive_seed(self.seed ^ 0x5348_5546, round),
            0,
        ));
        fy.shuffle(&mut messages);

        // --- analyzer ---------------------------------------------------
        self.last_traffic = traffic;
        Ok(self.analyzer.analyze(&messages))
    }

    /// Aggregate and also return the raw discretized sum readout (no
    /// decision clamping) — used by tests/benches in the Theorem 2 regime.
    pub fn aggregate_exact_bar(&mut self, xs: &[f64]) -> Result<(f64, u64), PipelineError> {
        let est = self.aggregate(xs)?;
        Ok((est, (est * self.plan.scale as f64).round() as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{forall, Gen};

    #[test]
    fn thm2_is_exact_up_to_rounding() {
        let plan = ProtocolPlan::theorem2(100, 1.0, 1e-6).unwrap();
        let k = plan.scale;
        let mut p = Pipeline::new(plan, 1);
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) / 100.0).collect();
        let est = p.aggregate(&xs).unwrap();
        let truth_bar: u64 = xs.iter().map(|&x| (x * k as f64).floor() as u64).sum();
        assert!((est - truth_bar as f64 / k as f64).abs() < 1e-9, "est={est}");
    }

    #[test]
    fn thm1_error_within_bound() {
        let plan = ProtocolPlan::theorem1(2_000, 1.0, 1e-6).unwrap();
        let bound = plan.error_bound();
        let mut p = Pipeline::new(plan, 2);
        let xs: Vec<f64> = (0..2_000).map(|i| ((i * 13) % 100) as f64 / 100.0).collect();
        let truth: f64 = xs.iter().sum();
        // average over a few rounds: expected error is O(bound)
        let mut worst: f64 = 0.0;
        for _ in 0..5 {
            let est = p.aggregate(&xs).unwrap();
            worst = worst.max((est - truth).abs());
        }
        // 6x headroom over the expected-error bound for a max-of-5 draw
        assert!(worst < 6.0 * bound + 1.0, "worst={worst} bound={bound}");
    }

    #[test]
    fn wrong_input_count_rejected() {
        let plan = ProtocolPlan::theorem2(10, 1.0, 1e-3).unwrap();
        let mut p = Pipeline::new(plan, 3);
        assert!(matches!(
            p.aggregate(&[0.5; 9]),
            Err(PipelineError::WrongInputCount { expected: 10, got: 9 })
        ));
    }

    #[test]
    fn traffic_accounting_matches_plan() {
        let plan = ProtocolPlan::theorem2(20, 1.0, 1e-4).unwrap();
        let m = plan.num_messages as u64;
        let mut p = Pipeline::new(plan, 4);
        p.aggregate(&vec![0.1; 20]).unwrap();
        assert_eq!(p.last_traffic.messages, 20 * m);
        assert_eq!(p.last_traffic.batches, 20);
    }

    #[test]
    fn prop_thm2_exactness_random_inputs() {
        forall("pipeline thm2 exact", 20, |g: &mut Gen| {
            let n = g.usize_in(2, 40);
            let plan = ProtocolPlan::theorem2(n, 0.5 + g.f64_unit(), 1e-4).unwrap();
            let k = plan.scale;
            let mut p = Pipeline::new(plan, g.seed());
            let xs: Vec<f64> = (0..n).map(|_| g.f64_unit()).collect();
            let est = p.aggregate(&xs).unwrap();
            let truth_bar: u64 = xs.iter().map(|&x| (x * k as f64).floor() as u64).sum();
            assert!((est - truth_bar as f64 / k as f64).abs() < 1e-9);
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let plan = ProtocolPlan::theorem1(50, 1.0, 1e-4).unwrap();
        let xs: Vec<f64> = vec![0.5; 50];
        let mut p1 = Pipeline::new(plan.clone(), 9);
        let mut p2 = Pipeline::new(plan, 9);
        assert_eq!(p1.aggregate(&xs).unwrap(), p2.aggregate(&xs).unwrap());
    }
}
