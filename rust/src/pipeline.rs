//! Single-shot aggregation pipeline — the library's simplest entry point.
//!
//! Since the engine refactor this type is a thin wrapper over
//! [`crate::engine::Engine`] with one shard and one aggregation instance:
//! Algorithm 1 (+ the §2.4 pre-randomizer when the plan is a Theorem 1
//! plan), the shuffler and Algorithm 2 in one call:
//!
//! ```
//! use cloak_agg::prelude::*;
//! let plan = ProtocolPlan::theorem2(50, 1.0, 1e-6).unwrap();
//! let mut p = Pipeline::new(plan, 7);
//! let xs = vec![0.5; 50];
//! let est = p.aggregate(&xs).unwrap();
//! assert!((est - 25.0).abs() <= 50.0 / 500.0); // n/k rounding only
//! ```
//!
//! The full streaming system (many aggregation instances, batching,
//! backpressure, shard parallelism) lives in [`crate::coordinator`] and
//! [`crate::engine`]; this type is the reference entry point the
//! integration tests compare them to. Like every frontend it is generic
//! over the [`Aggregator`](crate::aggregator::Aggregator) facade —
//! [`Pipeline::with_aggregator`] runs the same one-shot sums over a
//! cluster or elastic stack.

use crate::aggregator::{Aggregator, AggregatorError};
use crate::engine::{DerivedClientSeeds, Engine, EngineConfig, RoundInput};
use crate::params::ProtocolPlan;
use crate::transport::TrafficStats;

/// One-shot scalar aggregation under a [`ProtocolPlan`].
pub struct Pipeline {
    plan: ProtocolPlan,
    agg: Box<dyn Aggregator>,
    seeds: DerivedClientSeeds,
    /// Communication accounting for the last round.
    pub last_traffic: TrafficStats,
}

/// Pipeline failure modes.
#[derive(Debug, PartialEq)]
pub enum PipelineError {
    WrongInputCount { expected: usize, got: usize },
    /// The stack handed to [`Pipeline::with_aggregator`] is not a scalar
    /// (d = 1) profile.
    NotScalar { instances: usize },
    /// The aggregation stack failed the round (cluster/elastic backends
    /// can lose shards; the in-process engine cannot reach this).
    Agg(AggregatorError),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::WrongInputCount { expected, got } => {
                write!(f, "expected {expected} inputs (plan n), got {got}")
            }
            PipelineError::NotScalar { instances } => {
                write!(f, "pipeline needs a d = 1 stack, got {instances} instances")
            }
            PipelineError::Agg(e) => write!(f, "aggregator: {e}"),
        }
    }
}

impl std::error::Error for PipelineError {}

impl From<AggregatorError> for PipelineError {
    fn from(e: AggregatorError) -> Self {
        PipelineError::Agg(e)
    }
}

impl Pipeline {
    pub fn new(plan: ProtocolPlan, seed: u64) -> Self {
        let agg: Box<dyn Aggregator> =
            Box::new(Engine::new(EngineConfig::single(plan.clone()), seed));
        Pipeline {
            plan,
            agg,
            seeds: DerivedClientSeeds::new(seed),
            last_traffic: TrafficStats::default(),
        }
    }

    /// A pipeline over any scalar (d = 1) aggregation stack — typically
    /// from [`AggregatorBuilder`](crate::aggregator::AggregatorBuilder).
    /// `seed` derives the simulated cohort's client seeds; build the
    /// stack from the same seed for bit-identity with [`Pipeline::new`].
    pub fn with_aggregator(agg: Box<dyn Aggregator>, seed: u64) -> Result<Self, PipelineError> {
        let d = agg.config().instances;
        if d != 1 {
            return Err(PipelineError::NotScalar { instances: d });
        }
        Ok(Pipeline {
            plan: agg.config().plan.clone(),
            agg,
            seeds: DerivedClientSeeds::new(seed),
            last_traffic: TrafficStats::default(),
        })
    }

    pub fn plan(&self) -> &ProtocolPlan {
        &self.plan
    }

    /// Run one aggregation round over `xs` (one value in [0,1] per user).
    /// Returns the analyzer's estimate of Σ x_i.
    pub fn aggregate(&mut self, xs: &[f64]) -> Result<f64, PipelineError> {
        if xs.len() != self.plan.n {
            return Err(PipelineError::WrongInputCount { expected: self.plan.n, got: xs.len() });
        }
        let result = self.agg.run_round(&RoundInput::Scalars(xs), &self.seeds)?;
        self.last_traffic = result.traffic;
        Ok(result.estimates[0])
    }

    /// Aggregate and also return the raw discretized sum readout (no
    /// decision clamping) — used by tests/benches in the Theorem 2 regime.
    pub fn aggregate_exact_bar(&mut self, xs: &[f64]) -> Result<(f64, u64), PipelineError> {
        let est = self.aggregate(xs)?;
        Ok((est, (est * self.plan.scale as f64).round() as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{forall, Gen};

    #[test]
    fn thm2_is_exact_up_to_rounding() {
        let plan = ProtocolPlan::theorem2(100, 1.0, 1e-6).unwrap();
        let k = plan.scale;
        let mut p = Pipeline::new(plan, 1);
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) / 100.0).collect();
        let est = p.aggregate(&xs).unwrap();
        let truth_bar: u64 = xs.iter().map(|&x| (x * k as f64).floor() as u64).sum();
        assert!((est - truth_bar as f64 / k as f64).abs() < 1e-9, "est={est}");
    }

    #[test]
    fn thm1_error_within_bound() {
        let plan = ProtocolPlan::theorem1(2_000, 1.0, 1e-6).unwrap();
        let bound = plan.error_bound();
        let mut p = Pipeline::new(plan, 2);
        let xs: Vec<f64> = (0..2_000).map(|i| ((i * 13) % 100) as f64 / 100.0).collect();
        let truth: f64 = xs.iter().sum();
        // average over a few rounds: expected error is O(bound)
        let mut worst: f64 = 0.0;
        for _ in 0..5 {
            let est = p.aggregate(&xs).unwrap();
            worst = worst.max((est - truth).abs());
        }
        // 6x headroom over the expected-error bound for a max-of-5 draw
        assert!(worst < 6.0 * bound + 1.0, "worst={worst} bound={bound}");
    }

    #[test]
    fn wrong_input_count_rejected() {
        let plan = ProtocolPlan::theorem2(10, 1.0, 1e-3).unwrap();
        let mut p = Pipeline::new(plan, 3);
        assert!(matches!(
            p.aggregate(&[0.5; 9]),
            Err(PipelineError::WrongInputCount { expected: 10, got: 9 })
        ));
    }

    #[test]
    fn traffic_accounting_matches_plan() {
        let plan = ProtocolPlan::theorem2(20, 1.0, 1e-4).unwrap();
        let m = plan.num_messages as u64;
        let mut p = Pipeline::new(plan, 4);
        p.aggregate(&vec![0.1; 20]).unwrap();
        assert_eq!(p.last_traffic.messages, 20 * m);
        assert_eq!(p.last_traffic.batches, 20);
    }

    #[test]
    fn prop_thm2_exactness_random_inputs() {
        forall("pipeline thm2 exact", 20, |g: &mut Gen| {
            let n = g.usize_in(2, 40);
            let plan = ProtocolPlan::theorem2(n, 0.5 + g.f64_unit(), 1e-4).unwrap();
            let k = plan.scale;
            let mut p = Pipeline::new(plan, g.seed());
            let xs: Vec<f64> = (0..n).map(|_| g.f64_unit()).collect();
            let est = p.aggregate(&xs).unwrap();
            let truth_bar: u64 = xs.iter().map(|&x| (x * k as f64).floor() as u64).sum();
            assert!((est - truth_bar as f64 / k as f64).abs() < 1e-9);
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let plan = ProtocolPlan::theorem1(50, 1.0, 1e-4).unwrap();
        let xs: Vec<f64> = vec![0.5; 50];
        let mut p1 = Pipeline::new(plan.clone(), 9);
        let mut p2 = Pipeline::new(plan, 9);
        assert_eq!(p1.aggregate(&xs).unwrap(), p2.aggregate(&xs).unwrap());
    }

    #[test]
    fn pipeline_over_a_cluster_stack_matches_local() {
        use crate::aggregator::AggregatorBuilder;
        let plan = ProtocolPlan::theorem2(24, 1.0, 1e-4).unwrap();
        let xs: Vec<f64> = (0..24).map(|i| (i % 6) as f64 / 6.0).collect();
        let mut local = Pipeline::new(plan.clone(), 13);
        let stack = AggregatorBuilder::new(EngineConfig::single(plan.clone()), 13)
            .loopback()
            .build()
            .unwrap();
        let mut remote = Pipeline::with_aggregator(stack, 13).unwrap();
        assert_eq!(local.aggregate(&xs).unwrap(), remote.aggregate(&xs).unwrap());
        // a d > 1 stack is refused
        let wide = AggregatorBuilder::new(EngineConfig::new(plan, 3), 13).build().unwrap();
        assert!(matches!(
            Pipeline::with_aggregator(wide, 13),
            Err(PipelineError::NotScalar { instances: 3 })
        ));
    }

    #[test]
    fn pipeline_matches_engine_single_profile() {
        // The wrapper must be a pure delegation: a hand-built S=1/d=1
        // engine with the same seed produces the same estimate.
        let plan = ProtocolPlan::theorem2(30, 1.0, 1e-4).unwrap();
        let xs: Vec<f64> = (0..30).map(|i| (i % 5) as f64 / 5.0).collect();
        let mut p = Pipeline::new(plan.clone(), 11);
        let mut e = Engine::new(EngineConfig::single(plan), 11);
        let direct =
            e.run_round(&RoundInput::Scalars(&xs), &DerivedClientSeeds::new(11)).unwrap();
        assert_eq!(p.aggregate(&xs).unwrap(), direct.estimates[0]);
    }
}
