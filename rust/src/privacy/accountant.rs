//! Privacy accountant — composition of per-round (ε, δ) guarantees.
//!
//! §1.2: "in order to run gradient descent in a differentially private
//! manner, privacy parameters need to be chosen in such a way that the
//! combined privacy loss over many iterations is limited." The FL driver
//! registers every aggregation round here; the accountant reports the
//! running budget under both **basic composition** (Σε, Σδ) and **advanced
//! composition** (Dwork–Rothblum–Vadhan): for T executions of an
//! (ε, δ)-DP mechanism and slack δ′,
//!
//!   ε_total = √(2T·ln(1/δ′))·ε + T·ε·(e^ε − 1),  δ_total = T·δ + δ′.

use super::DpBudget;

/// Running composition state.
#[derive(Clone, Debug, Default)]
pub struct PrivacyAccountant {
    rounds: Vec<DpBudget>,
}

impl PrivacyAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one mechanism execution.
    pub fn spend(&mut self, b: DpBudget) {
        self.rounds.push(b);
    }

    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Basic composition: budgets add up.
    pub fn basic(&self) -> DpBudget {
        let epsilon = self.rounds.iter().map(|b| b.epsilon).sum();
        let delta = self.rounds.iter().map(|b| b.delta).sum::<f64>().min(1.0 - f64::EPSILON);
        DpBudget { epsilon, delta }
    }

    /// Advanced composition with slack `delta_prime`, assuming homogeneous
    /// rounds (uses the max per-round ε — exact when all rounds match,
    /// conservative otherwise).
    pub fn advanced(&self, delta_prime: f64) -> DpBudget {
        assert!(delta_prime > 0.0 && delta_prime < 1.0);
        let t = self.rounds.len() as f64;
        if self.rounds.is_empty() {
            return DpBudget { epsilon: 0.0, delta: 0.0 };
        }
        let eps = self.rounds.iter().map(|b| b.epsilon).fold(0.0f64, f64::max);
        let delta_sum: f64 = self.rounds.iter().map(|b| b.delta).sum();
        let epsilon = (2.0 * t * (1.0 / delta_prime).ln()).sqrt() * eps
            + t * eps * (eps.exp() - 1.0);
        DpBudget {
            epsilon,
            delta: (delta_sum + delta_prime).min(1.0 - f64::EPSILON),
        }
    }

    /// The tighter of basic vs advanced — what the FL driver logs.
    pub fn best(&self, delta_prime: f64) -> DpBudget {
        let b = self.basic();
        let a = self.advanced(delta_prime);
        if a.epsilon < b.epsilon {
            a
        } else {
            b
        }
    }

    /// Rounds of budget (ε, δ) each that fit inside `total` under advanced
    /// composition — the planner the FL example uses to pick a round count.
    pub fn max_rounds(per_round: DpBudget, total: DpBudget, delta_prime: f64) -> usize {
        let mut acc = PrivacyAccountant::new();
        let mut t = 0usize;
        loop {
            acc.spend(per_round);
            let spent = acc.best(delta_prime);
            if spent.epsilon > total.epsilon || spent.delta > total.delta {
                return t;
            }
            t += 1;
            if t > 1_000_000 {
                return t; // effectively unbounded
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_adds() {
        let mut a = PrivacyAccountant::new();
        a.spend(DpBudget::new(0.5, 1e-6));
        a.spend(DpBudget::new(0.25, 1e-7));
        let b = a.basic();
        assert!((b.epsilon - 0.75).abs() < 1e-12);
        assert!((b.delta - 1.1e-6).abs() < 1e-16);
    }

    #[test]
    fn advanced_beats_basic_for_many_small_rounds() {
        let mut a = PrivacyAccountant::new();
        for _ in 0..400 {
            a.spend(DpBudget::new(0.05, 1e-8));
        }
        let basic = a.basic();
        let adv = a.advanced(1e-6);
        assert!(adv.epsilon < basic.epsilon, "adv={} basic={}", adv.epsilon, basic.epsilon);
        // sanity: sqrt(2*400*ln 1e6)*0.05 + 400*0.05*(e^0.05-1) ≈ 5.3 + 1.03
        assert!(adv.epsilon < 7.0 && adv.epsilon > 4.0, "{}", adv.epsilon);
    }

    #[test]
    fn empty_accountant_is_free() {
        let a = PrivacyAccountant::new();
        assert_eq!(a.basic(), DpBudget { epsilon: 0.0, delta: 0.0 });
        assert_eq!(a.advanced(1e-9).epsilon, 0.0);
    }

    #[test]
    fn max_rounds_monotone_in_budget() {
        let per = DpBudget::new(0.1, 1e-9);
        let small = PrivacyAccountant::max_rounds(per, DpBudget::new(1.0, 1e-5), 1e-7);
        let large = PrivacyAccountant::max_rounds(per, DpBudget::new(4.0, 1e-5), 1e-7);
        assert!(small >= 1);
        assert!(large > small, "small={small} large={large}");
    }

    #[test]
    fn best_picks_smaller_epsilon() {
        let mut a = PrivacyAccountant::new();
        a.spend(DpBudget::new(2.0, 1e-8)); // single round: basic wins
        let b = a.best(1e-9);
        assert!((b.epsilon - 2.0).abs() < 1e-9);
    }
}
