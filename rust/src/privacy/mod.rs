//! Privacy machinery: the truncated discrete Laplace distribution
//! (Definition 3), the γ-smoothness estimator (Definition 2 / Lemma 1),
//! and the (ε, δ) accountant that composes the per-round guarantee across
//! federated-learning iterations (§1.2).

#![deny(clippy::redundant_clone)]

pub mod accountant;
pub mod dlaplace;
pub mod smoothness;

/// An (ε, δ) differential-privacy guarantee.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DpBudget {
    pub epsilon: f64,
    pub delta: f64,
}

impl DpBudget {
    pub fn new(epsilon: f64, delta: f64) -> Self {
        assert!(epsilon >= 0.0 && (0.0..1.0).contains(&delta));
        DpBudget { epsilon, delta }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_constructs() {
        let b = DpBudget::new(1.0, 1e-6);
        assert_eq!(b.epsilon, 1.0);
    }

    #[test]
    #[should_panic]
    fn budget_rejects_bad_delta() {
        DpBudget::new(1.0, 1.0);
    }
}
