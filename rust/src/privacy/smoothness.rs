//! Empirical γ-smoothness (Definition 2) — the engine behind Lemma 1's
//! guarantee and `benches/smoothness.rs`.
//!
//! A multiset E = {y_1, …, y_{2m}} is γ-smooth when the subset sums
//! X_I = Σ_{i∈I} y_i mod N over all I ∈ C([2m], m) are near-uniform on Z_N:
//! Pr_I[X_I = x] ∈ [(1−γ)/N, (1+γ)/N] for every x. This module enumerates
//! all C(2m, m) subsets (feasible for m ≤ 13: C(26,13) ≈ 10.4M) with a
//! Gosper's-hack walk and a running modular sum per subset, and reports
//! the empirical γ and duplicate status — exactly the two properties
//! Lemma 3 consumes.

use crate::arith::modring::ModRing;

/// Result of a smoothness measurement.
#[derive(Clone, Debug)]
pub struct SmoothnessReport {
    /// max_x |Pr_I[X_I = x]·N − 1| — the empirical γ.
    pub gamma: f64,
    /// Whether all 2m elements were distinct (the other half of the
    /// (Y choose 2m)_{γ-smooth} membership test).
    pub distinct: bool,
    /// Number of subsets enumerated, C(2m, m).
    pub subsets: u64,
    /// Histogram mass at the two *planted* sums (x1, x2 rows I_1, I_2 in
    /// Lemma 1) divided by uniform mass — should be ≈ 1 + O(γ).
    pub max_ratio: f64,
    pub min_ratio: f64,
}

/// Measure γ-smoothness of a 2m-element multiset over Z_N by exhaustive
/// subset enumeration. Panics if 2m > 26 (enumeration would be > 10^7·m).
pub fn measure(elements: &[u64], modulus: u64) -> SmoothnessReport {
    let two_m = elements.len();
    assert!(two_m % 2 == 0 && two_m >= 4, "need an even number >= 4 of elements");
    assert!(two_m <= 26, "enumeration bounded to 2m <= 26, got {two_m}");
    let m = two_m / 2;
    let ring = ModRing::new(modulus);
    let reduced: Vec<u64> = elements.iter().map(|&e| ring.reduce(e)).collect();

    // Distinctness check.
    let mut sorted = reduced.clone();
    sorted.sort_unstable();
    let distinct = sorted.windows(2).all(|w| w[0] != w[1]);

    // Histogram of X_I over all I in C([2m], m) via Gosper's hack.
    let mut hist = vec![0u64; modulus as usize];
    let mut subsets = 0u64;
    let mut mask: u64 = (1u64 << m) - 1;
    let limit: u64 = 1u64 << two_m;
    while mask < limit {
        let mut acc = 0u64;
        let mut bits = mask;
        while bits != 0 {
            let i = bits.trailing_zeros() as usize;
            acc = ring.add(acc, reduced[i]);
            bits &= bits - 1;
        }
        hist[acc as usize] += 1;
        subsets += 1;
        // Gosper: next subset of the same popcount.
        let c = mask & mask.wrapping_neg();
        let r = mask + c;
        mask = (((r ^ mask) >> 2) / c) | r;
    }

    let uniform = subsets as f64 / modulus as f64;
    let mut max_ratio = f64::MIN;
    let mut min_ratio = f64::MAX;
    for &h in &hist {
        let ratio = h as f64 / uniform;
        max_ratio = max_ratio.max(ratio);
        min_ratio = min_ratio.min(ratio);
    }
    let gamma = (max_ratio - 1.0).max(1.0 - min_ratio);
    SmoothnessReport { gamma, distinct, subsets, max_ratio, min_ratio }
}

/// Lemma 1's failure-probability bound for the chosen (m, N, γ):
/// Pr[not γ-smooth or duplicates] < 2m²/N + 18√m·N²/(γ²·2^{2m}).
pub fn lemma1_failure_bound(m: usize, modulus: u64, gamma: f64) -> f64 {
    let mf = m as f64;
    let nf = modulus as f64;
    let term1 = 2.0 * mf * mf / nf;
    // compute 18√m·N²/(γ²·2^{2m}) in log2 space to dodge overflow
    let log2_term2 = (18.0 * mf.sqrt()).log2() + 2.0 * nf.log2() - 2.0 * gamma.log2() - 2.0 * mf;
    term1 + if log2_term2 < -1074.0 { 0.0 } else { log2_term2.exp2() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::CloakEncoder;
    use crate::rng::{ChaCha20Rng, SeedableRng};

    #[test]
    fn binomial_count_is_exact() {
        // 2m = 8, m = 4: C(8,4) = 70 subsets.
        let r = measure(&[1, 2, 3, 4, 5, 6, 7, 8], 31);
        assert_eq!(r.subsets, 70);
        assert!(r.distinct);
    }

    #[test]
    fn duplicates_detected() {
        let r = measure(&[1, 1, 3, 4, 5, 6, 7, 8], 31);
        assert!(!r.distinct);
    }

    #[test]
    fn encoder_pairs_are_smooth_whp() {
        // Lemma 1 regime: m = 12, N = 31 => 2^{2m} = 16.7M >> N^2 = 961.
        // The union of two encodings should be ~N^{-1}-smooth-ish; we only
        // assert gamma is small (subset-sum equidistribution), since a
        // single draw has sampling noise ~ sqrt(N/C(2m,m)).
        let m = 12;
        let n_mod = 31u64;
        let enc = CloakEncoder::new(n_mod, 10, m);
        let mut rng = ChaCha20Rng::seed_from_u64(5);
        let mut e = enc.encode_scalar(0.4, &mut rng);
        e.extend(enc.encode_scalar(0.9, &mut rng));
        let r = measure(&e, n_mod);
        assert_eq!(r.subsets, 2_704_156); // C(24,12)
        // planted sums contribute ~2 subsets of 2.7M: gamma should be tiny
        assert!(r.gamma < 0.02, "gamma={}", r.gamma);
    }

    #[test]
    fn planted_sums_present() {
        // The defining property: subsets I_1 = first half, I_2 = second
        // half hit exactly x1', x2'. measure() can't see which subset is
        // which, but the histogram mass at x1+x2's split values must be >0.
        let m = 6;
        let n_mod = 13u64;
        let enc = CloakEncoder::new(n_mod, 10, m);
        let mut rng = ChaCha20Rng::seed_from_u64(7);
        let x1 = 0.3;
        let ys1 = enc.encode_scalar(x1, &mut rng);
        let sum1 = enc.ring().sum(&ys1);
        assert_eq!(sum1, enc.codec().encode(x1) % n_mod);
    }

    #[test]
    fn lemma1_bound_shrinks_with_m() {
        let b8 = lemma1_failure_bound(8, 1009, 0.1);
        let b12 = lemma1_failure_bound(12, 1009, 0.1);
        assert!(b12 < b8);
    }

    #[test]
    fn constant_multiset_is_maximally_unsmooth() {
        // all elements equal -> every size-m subset has the same sum
        let r = measure(&[5u64; 12], 31);
        assert!(!r.distinct);
        assert!(r.gamma > 10.0, "gamma={}", r.gamma); // all mass on one x
    }
}
