//! The truncated discrete Laplace distribution D_{N,p} (Definition 3).
//!
//! pmf: D_{N,p}[k] = (1−p)·p^|k| / (1 + p − 2·p^{(N+1)/2}) on the integer
//! interval I = {−(N−1)/2, …, +(N−1)/2}.
//!
//! Lemma 7 (log-Lipschitzness) and Lemma 8 (zero mean, variance bound
//! 2p(1+p)/((1−p)²(1+p−2p^{(N+1)/2}))) are verified by the unit tests.
//!
//! Sampling uses the two-sided-geometric construction with rejection of
//! out-of-interval magnitudes: draw magnitude g ~ Geom(1−p), sign s = ±1,
//! reject (g=0, s=−1) to avoid double-counting zero, reject g > (N−1)/2.
//! The geometric is drawn by inversion, g = ⌊ln(U)/ln(p)⌋, which is exact
//! up to f64 rounding — adequate for a simulation testbed (a hardened
//! deployment would use a constant-time exact sampler; see DESIGN.md §3).

use crate::rng::Rng;

/// Truncated discrete Laplace sampler + closed-form moments.
#[derive(Clone, Debug)]
pub struct TruncatedDiscreteLaplace {
    /// Ring size N (odd): support is ±(N−1)/2.
    modulus: u64,
    /// Geometric decay p ∈ (0, 1).
    p: f64,
}

impl TruncatedDiscreteLaplace {
    pub fn new(modulus: u64, p: f64) -> Self {
        assert!(modulus % 2 == 1, "N must be odd");
        assert!(p > 0.0 && p < 1.0, "p must be in (0,1), got {p}");
        TruncatedDiscreteLaplace { modulus, p }
    }

    pub fn p(&self) -> f64 {
        self.p
    }

    /// Half-width of the support: (N−1)/2.
    pub fn half_width(&self) -> u64 {
        (self.modulus - 1) / 2
    }

    /// Normalizing constant denominator 1 + p − 2·p^{(N+1)/2}.
    fn norm_denom(&self) -> f64 {
        let half_plus = (self.modulus as f64 + 1.0) / 2.0;
        1.0 + self.p - 2.0 * self.p.powf(half_plus)
    }

    /// pmf at integer k (0 outside the support) — Definition 3, Eq. (15).
    pub fn pmf(&self, k: i64) -> f64 {
        if k.unsigned_abs() > self.half_width() {
            return 0.0;
        }
        (1.0 - self.p) * self.p.powi(k.unsigned_abs().min(i32::MAX as u64) as i32)
            / self.norm_denom()
    }

    /// Closed-form variance bound from Lemma 8 (the true variance is ≤ this).
    pub fn variance(&self) -> f64 {
        let p = self.p;
        2.0 * p * (1.0 + p) / ((1.0 - p) * (1.0 - p) * self.norm_denom())
    }

    /// Draw one sample.
    pub fn sample<R: Rng>(&self, rng: &mut R) -> i64 {
        let half = self.half_width();
        let ln_p = self.p.ln();
        loop {
            // magnitude ~ Geom(1−p): P(g) = (1−p)p^g
            let u = {
                // avoid ln(0)
                let mut v = rng.gen_f64();
                while v <= 0.0 {
                    v = rng.gen_f64();
                }
                v
            };
            let g = (u.ln() / ln_p).floor();
            if !(g >= 0.0) || g > half as f64 {
                continue; // truncation rejection
            }
            let g = g as u64;
            let negative = rng.gen_bool(0.5);
            if g == 0 && negative {
                continue; // avoid double-counting zero
            }
            return if negative { -(g as i64) } else { g as i64 };
        }
    }

    /// Expected |X| (used for the Thm 1 error-bound curve): for a zero-mean
    /// X, E|X| ≤ sqrt(Var X); we report the exact sum when cheap.
    pub fn expected_abs(&self) -> f64 {
        // Exact for small N; bound otherwise.
        if self.modulus <= 20_001 {
            let mut s = 0.0;
            for k in 1..=self.half_width() as i64 {
                s += 2.0 * k as f64 * self.pmf(k);
            }
            s
        } else {
            self.variance().sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{ChaCha20Rng, SeedableRng};
    use crate::util::Welford;

    #[test]
    fn pmf_normalizes() {
        for &(n, p) in &[(101u64, 0.5f64), (1001, 0.9), (51, 0.99)] {
            let d = TruncatedDiscreteLaplace::new(n, p);
            let total: f64 = (-(d.half_width() as i64)..=d.half_width() as i64)
                .map(|k| d.pmf(k))
                .sum();
            assert!((total - 1.0).abs() < 1e-9, "n={n} p={p} total={total}");
        }
    }

    #[test]
    fn pmf_symmetric_and_zero_outside() {
        let d = TruncatedDiscreteLaplace::new(101, 0.8);
        for k in 1..=50i64 {
            assert_eq!(d.pmf(k), d.pmf(-k));
        }
        assert_eq!(d.pmf(51), 0.0);
        assert_eq!(d.pmf(-51), 0.0);
    }

    #[test]
    fn lemma7_log_lipschitz() {
        // p^|t| <= pmf(k+t mod I)/pmf(k mod I) <= p^{-|t|}
        let n = 101u64;
        let p = 0.7;
        let d = TruncatedDiscreteLaplace::new(n, p);
        let half = d.half_width() as i64;
        let wrap = |v: i64| -> i64 {
            // reduce into I = [-half, half]
            let m = n as i64;
            let mut r = v % m;
            if r > half {
                r -= m;
            }
            if r < -half {
                r += m;
            }
            r
        };
        for k in 0..n as i64 {
            for t in [-half, -10, -1, 0, 1, 10, half] {
                let num = d.pmf(wrap(k + t));
                let den = d.pmf(wrap(k));
                let ratio = num / den;
                let lo = p.powi(t.unsigned_abs() as i32);
                let hi = p.powi(-(t.unsigned_abs() as i32));
                assert!(
                    ratio >= lo * (1.0 - 1e-9) && ratio <= hi * (1.0 + 1e-9),
                    "k={k} t={t} ratio={ratio} in [{lo},{hi}]?"
                );
            }
        }
    }

    #[test]
    fn lemma8_moments_empirical() {
        let d = TruncatedDiscreteLaplace::new(10_001, 0.95);
        let mut rng = ChaCha20Rng::seed_from_u64(1);
        let mut w = Welford::default();
        for _ in 0..200_000 {
            w.push(d.sample(&mut rng) as f64);
        }
        // zero mean
        let sem = w.std_dev() / (w.count() as f64).sqrt();
        assert!(w.mean().abs() < 5.0 * sem, "mean={} sem={}", w.mean(), sem);
        // variance below the Lemma 8 bound, and not absurdly below
        assert!(w.variance() <= d.variance() * 1.05, "{} vs {}", w.variance(), d.variance());
        assert!(w.variance() >= d.variance() * 0.5);
    }

    #[test]
    fn samples_within_support() {
        let d = TruncatedDiscreteLaplace::new(11, 0.9999);
        let mut rng = ChaCha20Rng::seed_from_u64(2);
        for _ in 0..5000 {
            let s = d.sample(&mut rng);
            assert!(s.abs() <= 5, "{s}");
        }
    }

    #[test]
    fn empirical_pmf_matches_closed_form() {
        let d = TruncatedDiscreteLaplace::new(21, 0.6);
        let mut rng = ChaCha20Rng::seed_from_u64(3);
        let trials = 400_000;
        let mut counts = std::collections::HashMap::new();
        for _ in 0..trials {
            *counts.entry(d.sample(&mut rng)).or_insert(0u64) += 1;
        }
        for k in -10..=10i64 {
            let want = d.pmf(k);
            let got = *counts.get(&k).unwrap_or(&0) as f64 / trials as f64;
            let sd = (want * (1.0 - want) / trials as f64).sqrt();
            assert!((got - want).abs() < 6.0 * sd + 1e-4, "k={k} got={got} want={want}");
        }
    }

    #[test]
    fn expected_abs_close_to_std() {
        let d = TruncatedDiscreteLaplace::new(10_001, 0.9);
        let ea = d.expected_abs();
        let sd = d.variance().sqrt();
        assert!(ea > 0.0 && ea <= sd * 1.01, "ea={ea} sd={sd}");
    }
}
