//! The elastic control plane — shard health, live re-ranging, and
//! surviving-shard takeover for cluster rounds.
//!
//! [`crate::cluster`] gave the engine multi-host shards behind a
//! straggler-tolerant barrier, but the fleet was *rigid*: ranges were
//! fixed at construction and a shard lost past the retry budget failed
//! the whole round. This subsystem makes the fleet elastic. It sits
//! between [`ClusterEngine`](crate::cluster::ClusterEngine) and its
//! transport backend, deciding per round *where* work runs — which the
//! paper's construction makes safe to do freely: every user's
//! contribution is a self-contained set of noise-masked shares, and the
//! analyzer's modular sum is permutation-invariant, so the merged
//! estimates are **indifferent to which shard executes an instance
//! range**. Moving ranges between shards (or splitting a lost range
//! across survivors mid-round) changes wall-clock and failure exposure,
//! never bits.
//!
//! # Architecture
//!
//! ```text
//!  ClusterEngine ── plan_ranges(round) ──► ElasticController
//!       │                                   │  ├─ ShardDirectory
//!       │ work units (per planned range)    │  │    per-link: alive,
//!       ▼                                   │  │    latency EWMA,
//!  ShardBackend::run_shards                 │  │    failures, takeovers
//!       │                                   │  └─ RebalancePolicy
//!       ▼                                   │       Static / EvenSplit /
//!  ElasticController::run_shards            │       Proportional
//!       │ run_attempts (per-unit outcomes)  │
//!       ▼                                   ▼
//!  RemoteShardBackend ── links ──► ShardServer fleet
//!       │ lost unit?  slice × survivors, virtual shard ids,
//!       │             handshake-as-placement, execute, stitch
//!       └────────────► ShardOut(lost shard) — merge never knows
//! ```
//!
//! Three moving parts:
//!
//! * [`ShardDirectory`] — per-link health observed from barrier outcomes:
//!   a latency EWMA over the shard-reported compute wall, consecutive and
//!   total losses, takeover slices absorbed, liveness. Updated by the
//!   controller on every work-unit outcome.
//! * [`RebalancePolicy`] ([`StaticRanges`], [`EvenSplit`],
//!   [`Proportional`]) — re-partitions the d instances into per-link
//!   ranges at round boundaries, via
//!   [`ShardBackend::plan_ranges`](crate::engine::ShardBackend::plan_ranges).
//!   Dead links are parked (empty range) and re-offered work every
//!   [`ElasticTuning::revive_every`] rounds — a recovered link rejoins by
//!   simply answering; a still-dead one fails back into the takeover path.
//! * [`ElasticController`] — the [`ShardBackend`](crate::engine::ShardBackend)
//!   wrapper that drives
//!   [`RemoteShardBackend::run_attempts`](crate::cluster::RemoteShardBackend::run_attempts)
//!   (per-unit outcomes instead of round failure) and performs **in-round
//!   takeover**: a unit lost past the retry budget is
//!   [`slice`](crate::engine::ShardRoundWork::slice)d across surviving
//!   links under fresh virtual shard ids and its output stitched back
//!   together, so the round completes bit-identical to the never-failed
//!   run. Work units carry all their seeds, which is what makes the
//!   re-execution retry-safe and duplicate-proof.
//!
//! # Handshake: identity vs placement
//!
//! Re-ranging leans on the split documented in
//! [`cluster::shard_server`](crate::cluster::shard_server): the config
//! fingerprint covers protocol *identity* only, while *placement* (shard
//! id → instance range) is mutable, plural per server, established by
//! `ShardAssign` and dropped by `ShardRetire`. A takeover round leaves a
//! survivor holding its own placement plus one-shot virtual placements
//! for the slices it absorbed; the controller retires them once the
//! range is stitched.
//!
//! # Trust model
//!
//! The controller adds **no new observer** to the protocol. It consumes
//! only link-level telemetry — who answered, how fast, how often frames
//! were lost — never client data: shares stay inside the work units it
//! forwards opaquely, and per-range estimates pass through it exactly as
//! they pass through the barrier it wraps. A malicious controller could
//! degrade liveness (park healthy shards, route all work to one place)
//! but cannot weaken the shuffled-model guarantee, which is enforced
//! below it: every shard shuffles each instance pool before its analyzer
//! reads it, wherever the range lands. Re-ranging also never changes the
//! DP accounting — the noise is per (client, instance, round), carried in
//! the shares themselves.

#![deny(clippy::redundant_clone)]

pub mod controller;
pub mod directory;
pub mod policy;

pub use controller::{ElasticController, ElasticTuning};
pub use directory::ShardDirectory;
pub use policy::{EvenSplit, Proportional, RebalancePolicy, StaticRanges};

/// Re-exported from [`crate::engine`], which owns the record type its
/// [`ShardBackend`](crate::engine::ShardBackend) seam reports.
pub use crate::engine::ShardHealth;
