//! Rebalance policies — how the d instances are re-partitioned across
//! shard links at round boundaries, given the directory's health view.
//!
//! A policy returns one contiguous `(lo, hi)` per link, tiling
//! `[0, instances)` in link order; `lo == hi` parks that link for the
//! round. Re-partitioning is always estimate-safe: shares are a pure
//! function of `(client, instance, round)` and the analyzer's modular sum
//! is permutation-invariant, so any tiling produces bit-identical merged
//! estimates (see [`crate::engine::ShardRoundWork::slice`]). Policies only
//! move *wall-clock and failure exposure*, never bits.

use crate::engine::{shard_ranges, ShardHealth};

/// A round-boundary re-partitioning strategy.
pub trait RebalancePolicy {
    /// Label for reports and benches ("static", "even-split", …).
    fn label(&self) -> &'static str;

    /// Partition `instances` across `shards.len()` links. Must return one
    /// range per link, tiling `[0, instances)` contiguously in link order
    /// (the cluster engine validates and falls back to the static layout
    /// on a malformed tiling).
    fn partition(&self, instances: usize, shards: &[ShardHealth]) -> Vec<(usize, usize)>;
}

/// No elasticity: the engine's static near-equal layout, regardless of
/// health. Dead shards keep their ranges, so every round they stay dead
/// pays the retry budget and a takeover — the baseline the elastic
/// policies are measured against.
pub struct StaticRanges;

impl RebalancePolicy for StaticRanges {
    fn label(&self) -> &'static str {
        "static"
    }

    fn partition(&self, instances: usize, shards: &[ShardHealth]) -> Vec<(usize, usize)> {
        ranges_for_spans(&even_spans(instances, &vec![true; shards.len()]))
    }
}

/// Even split over the links currently alive; dead links are parked
/// (empty range) until they rejoin.
pub struct EvenSplit;

impl RebalancePolicy for EvenSplit {
    fn label(&self) -> &'static str {
        "even-split"
    }

    fn partition(&self, instances: usize, shards: &[ShardHealth]) -> Vec<(usize, usize)> {
        ranges_for_spans(&even_spans(instances, &alive_mask(shards)))
    }
}

/// Latency-weighted split: alive links get spans proportional to the
/// inverse of their compute-wall EWMA (a shard twice as fast gets twice
/// the instances), apportioned by largest remainder so spans are integral,
/// deterministic and sum to `instances`. Links with no sample yet weigh as
/// the average sampled latency (a fresh or just-rejoined shard is assumed
/// ordinary, not infinitely fast), and — when there are at least as many
/// instances as alive links — every alive link keeps a floor of one
/// instance, so its latency stays measured and one bad EWMA can never
/// starve it permanently.
pub struct Proportional {
    /// Latency floor in seconds — caps any single link's weight so one
    /// near-zero EWMA cannot starve the rest of the fleet.
    pub floor_s: f64,
}

impl Default for Proportional {
    fn default() -> Self {
        Proportional { floor_s: 1e-6 }
    }
}

impl RebalancePolicy for Proportional {
    fn label(&self) -> &'static str {
        "proportional"
    }

    fn partition(&self, instances: usize, shards: &[ShardHealth]) -> Vec<(usize, usize)> {
        let mask = alive_mask(shards);
        let sampled: Vec<f64> = shards
            .iter()
            .zip(&mask)
            .filter(|(s, &a)| a && s.latency_ewma_s > 0.0)
            .map(|(s, _)| s.latency_ewma_s)
            .collect();
        let default_lat = if sampled.is_empty() {
            1.0
        } else {
            sampled.iter().sum::<f64>() / sampled.len() as f64
        };
        let weights: Vec<f64> = shards
            .iter()
            .zip(&mask)
            .map(|(s, &a)| {
                if !a {
                    0.0
                } else {
                    let lat = if s.latency_ewma_s > 0.0 { s.latency_ewma_s } else { default_lat };
                    1.0 / lat.max(self.floor_s)
                }
            })
            .collect();
        let alive_n = mask.iter().filter(|&&a| a).count();
        if alive_n == 0 || instances < alive_n {
            return ranges_for_spans(&even_spans(instances, &mask));
        }
        // One-instance floor per alive link, remainder by weight.
        let mut spans = apportion(instances - alive_n, &weights);
        for (span, &a) in spans.iter_mut().zip(&mask) {
            if a {
                *span += 1;
            }
        }
        ranges_for_spans(&spans)
    }
}

/// Liveness mask with a last-resort fallback: a fleet where *every* link
/// is marked dead still has to run somewhere, so it is treated as fully
/// alive (the barrier's own loss handling then decides the round's fate).
fn alive_mask(shards: &[ShardHealth]) -> Vec<bool> {
    if shards.iter().any(|s| s.alive) {
        shards.iter().map(|s| s.alive).collect()
    } else {
        vec![true; shards.len()]
    }
}

/// Near-equal spans over the `true` entries of `mask`; `false` entries
/// get 0.
fn even_spans(instances: usize, mask: &[bool]) -> Vec<usize> {
    let alive = mask.iter().filter(|&&a| a).count().max(1);
    let shares = shard_ranges(instances, alive.min(instances.max(1)));
    let mut spans = vec![0usize; mask.len()];
    let mut next = shares.iter().map(|(lo, hi)| hi - lo);
    for (span, &a) in spans.iter_mut().zip(mask) {
        if a {
            *span = next.next().unwrap_or(0);
        }
    }
    spans
}

/// Largest-remainder apportionment of `total` into integer spans
/// proportional to `weights` — deterministic (ties break on index) and
/// exactly summing to `total`. A zero/negative weight sum falls back to an
/// even split over all entries.
fn apportion(total: usize, weights: &[f64]) -> Vec<usize> {
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        return even_spans(total, &vec![true; weights.len()]);
    }
    let quotas: Vec<f64> = weights.iter().map(|w| total as f64 * w / wsum).collect();
    let mut spans: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let assigned: usize = spans.iter().sum();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.total_cmp(&fa).then(a.cmp(&b))
    });
    for &i in order.iter().take(total.saturating_sub(assigned)) {
        spans[i] += 1;
    }
    spans
}

/// Cumulative contiguous ranges from per-link spans.
fn ranges_for_spans(spans: &[usize]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::with_capacity(spans.len());
    let mut lo = 0usize;
    for &span in spans {
        ranges.push((lo, lo + span));
        lo += span;
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ranges_tile;

    fn health(alive: &[bool], ewma: &[f64]) -> Vec<ShardHealth> {
        alive
            .iter()
            .zip(ewma)
            .map(|(&a, &l)| ShardHealth { alive: a, latency_ewma_s: l, ..Default::default() })
            .collect()
    }

    #[test]
    fn static_ranges_match_engine_layout() {
        let h = health(&[true, false, true], &[0.0; 3]);
        let ranges = StaticRanges.partition(7, &h);
        assert_eq!(ranges, vec![(0, 3), (3, 5), (5, 7)], "health is ignored");
        assert!(ranges_tile(&ranges, 7));
    }

    #[test]
    fn even_split_parks_dead_links() {
        let h = health(&[true, false, true, true], &[0.0; 4]);
        let ranges = EvenSplit.partition(9, &h);
        assert!(ranges_tile(&ranges, 9));
        assert_eq!(ranges[1].0, ranges[1].1, "dead link is parked");
        let spans: Vec<usize> = ranges.iter().map(|(lo, hi)| hi - lo).collect();
        assert_eq!(spans, vec![3, 0, 3, 3]);
    }

    #[test]
    fn even_split_with_no_survivors_falls_back_to_everyone() {
        let h = health(&[false, false], &[0.0; 2]);
        let ranges = EvenSplit.partition(4, &h);
        assert_eq!(ranges, vec![(0, 2), (2, 4)], "all-dead fleet runs as if alive");
    }

    #[test]
    fn proportional_gives_slow_shards_fewer_instances() {
        // Link 1 is 3× slower than links 0 and 2.
        let h = health(&[true, true, true], &[0.1, 0.3, 0.1]);
        let ranges = Proportional::default().partition(14, &h);
        assert!(ranges_tile(&ranges, 14));
        let spans: Vec<usize> = ranges.iter().map(|(lo, hi)| hi - lo).collect();
        assert_eq!(spans.iter().sum::<usize>(), 14);
        assert!(spans[1] < spans[0] && spans[1] < spans[2], "slow link shrinks: {spans:?}");
        // weights 10:3.33:10 → quotas 6:2:6
        assert_eq!(spans, vec![6, 2, 6]);
    }

    #[test]
    fn proportional_without_samples_is_even_and_deterministic() {
        let h = health(&[true, true, true, false], &[0.0; 4]);
        let a = Proportional::default().partition(10, &h);
        let b = Proportional::default().partition(10, &h);
        assert_eq!(a, b);
        assert!(ranges_tile(&a, 10));
        assert_eq!(a[3].0, a[3].1, "dead link parked");
        let spans: Vec<usize> = a.iter().map(|(lo, hi)| hi - lo).collect();
        assert_eq!(spans.iter().filter(|&&s| s > 0).count(), 3);
        let max = spans.iter().max().unwrap();
        let min = spans.iter().filter(|&&s| s > 0).min().unwrap();
        assert!(max - min <= 1, "unsampled fleet splits evenly: {spans:?}");
    }

    #[test]
    fn proportional_never_starves_an_alive_link() {
        // A link 10⁴× slower than its peers still keeps one instance, so
        // its EWMA keeps refreshing and it can earn its way back.
        let h = health(&[true, true], &[1e-4, 1.0]);
        let ranges = Proportional::default().partition(4, &h);
        let spans: Vec<usize> = ranges.iter().map(|(lo, hi)| hi - lo).collect();
        assert_eq!(spans, vec![3, 1], "floor of one instance per alive link");
        assert!(ranges_tile(&ranges, 4));
    }

    #[test]
    fn apportion_sums_and_breaks_ties_by_index() {
        assert_eq!(apportion(10, &[1.0, 1.0, 1.0, 1.0]), vec![3, 3, 2, 2]);
        assert_eq!(apportion(0, &[1.0, 2.0]), vec![0, 0]);
        assert_eq!(apportion(5, &[0.0, 0.0]), vec![3, 2], "zero weights fall back to even");
        for total in [1usize, 7, 64] {
            let spans = apportion(total, &[0.7, 0.1, 3.0, 0.0]);
            assert_eq!(spans.iter().sum::<usize>(), total);
            assert_eq!(spans[3], 0, "zero weight gets nothing when others exist");
        }
    }
}
