//! The shard directory — per-shard health observed from barrier outcomes.
//!
//! The directory is the control plane's *only* input: it sees which links
//! answered (and how fast, via the shard-reported compute wall), which
//! stayed silent past the retry budget, and which absorbed takeover
//! slices. It never sees client data, shares, or estimates — see the
//! trust-model notes in [`super`].
//!
//! The record type itself ([`ShardHealth`]) lives with the
//! [`ShardBackend`](crate::engine::ShardBackend) seam in
//! [`crate::engine`], which reports it — the dependency arrow points
//! engine ← control, never back.

use crate::engine::ShardHealth;

/// Health table for a fleet of shard links, indexed by link id.
pub struct ShardDirectory {
    shards: Vec<ShardHealth>,
    /// EWMA smoothing factor in (0, 1]: weight of the newest sample.
    alpha: f64,
}

impl ShardDirectory {
    pub fn new(links: usize, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha must be in (0, 1]");
        ShardDirectory { shards: vec![ShardHealth::default(); links], alpha }
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn health(&self) -> &[ShardHealth] {
        &self.shards
    }

    pub fn snapshot(&self) -> Vec<ShardHealth> {
        self.shards.clone()
    }

    pub fn alive(&self, link: usize) -> bool {
        self.shards[link].alive
    }

    /// Link ids currently considered alive, in id order.
    pub fn alive_links(&self) -> Vec<usize> {
        (0..self.shards.len()).filter(|&i| self.shards[i].alive).collect()
    }

    /// A work unit completed on `link`; `wall_ns` is its latency sample —
    /// the controller passes the shard-reported compute wall normalized
    /// per instance, so the EWMA estimates *speed*, not range size.
    /// Marks the link alive (a reply from a dead-marked link IS the
    /// rejoin signal) and folds the sample into the EWMA.
    pub fn record_success(&mut self, link: usize, wall_ns: u64) {
        let s = &mut self.shards[link];
        s.alive = true;
        s.consecutive_failures = 0;
        s.rounds_ok += 1;
        let sample = wall_ns as f64 * 1e-9;
        s.latency_ewma_s = if s.latency_ewma_s == 0.0 {
            sample
        } else {
            self.alpha * sample + (1.0 - self.alpha) * s.latency_ewma_s
        };
    }

    /// A work unit on `link` was lost past the whole retry budget: mark
    /// the link dead so the policy stops routing ranges at it.
    pub fn record_failure(&mut self, link: usize) {
        let s = &mut self.shards[link];
        s.alive = false;
        s.consecutive_failures += 1;
        s.failures += 1;
    }

    /// `link` absorbed one takeover slice for a lost peer.
    pub fn record_takeover(&mut self, link: usize) {
        self.shards[link].takeovers_absorbed += 1;
    }

    /// Optimistically mark every link alive again — the probe-by-offering
    /// move: a still-dead link fails its next work unit and drops straight
    /// back out (the takeover path absorbs the cost), a recovered one
    /// rejoins with no separate probe protocol.
    pub fn revive_all(&mut self) {
        for s in &mut self.shards {
            if !s.alive {
                s.alive = true;
                s.consecutive_failures = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn success_marks_alive_and_tracks_ewma() {
        let mut d = ShardDirectory::new(2, 0.5);
        d.record_success(0, 1_000_000_000); // 1 s
        assert!((d.health()[0].latency_ewma_s - 1.0).abs() < 1e-12, "first sample seeds");
        d.record_success(0, 3_000_000_000); // 3 s
        assert!((d.health()[0].latency_ewma_s - 2.0).abs() < 1e-12, "0.5·3 + 0.5·1");
        assert_eq!(d.health()[0].rounds_ok, 2);
        assert_eq!(d.health()[1].rounds_ok, 0, "other links untouched");
    }

    #[test]
    fn failure_marks_dead_and_reply_rejoins() {
        let mut d = ShardDirectory::new(3, 0.3);
        d.record_failure(1);
        d.record_failure(1);
        assert!(!d.alive(1));
        assert_eq!(d.alive_links(), vec![0, 2]);
        assert_eq!(d.health()[1].consecutive_failures, 2);
        assert_eq!(d.health()[1].failures, 2);
        // A successful reply is the rejoin signal.
        d.record_success(1, 5);
        assert!(d.alive(1));
        assert_eq!(d.health()[1].consecutive_failures, 0);
        assert_eq!(d.health()[1].failures, 2, "history is kept");
    }

    #[test]
    fn revive_all_resets_only_liveness() {
        let mut d = ShardDirectory::new(2, 0.3);
        d.record_failure(0);
        d.record_takeover(1);
        d.revive_all();
        assert!(d.alive(0));
        assert_eq!(d.health()[0].failures, 1, "failure history survives revival");
        assert_eq!(d.health()[1].takeovers_absorbed, 1);
    }
}
