//! The elastic controller — a [`ShardBackend`] that wraps
//! [`RemoteShardBackend`] with health tracking, round-boundary
//! re-ranging, and in-round takeover of lost ranges.
//!
//! The controller turns the barrier's per-unit outcomes
//! ([`RemoteShardBackend::run_attempts`]) into fleet decisions:
//!
//! * every outcome feeds the [`ShardDirectory`] (latency EWMA from the
//!   shard-reported compute wall, loss counts, liveness);
//! * at each round boundary the [`RebalancePolicy`] re-partitions the d
//!   instances over the links the directory considers alive
//!   ([`ShardBackend::plan_ranges`]);
//! * when a unit is lost past the whole retry budget, the controller
//!   **re-scatters the lost range to survivors** instead of failing the
//!   round: the lost work is [`slice`](ShardRoundWork::slice)d into
//!   sub-ranges under fresh *virtual shard ids*, handshaken onto surviving
//!   links as additional placements, executed, and stitched back into the
//!   lost shard's [`ShardOutMsg`] — so the caller's barrier merge never
//!   learns anything happened. Retry-safe and bit-identical because work
//!   units carry all their seeds and the analyzer's modular sum is
//!   permutation-invariant.
//!
//! Dead links rejoin by *offering*: every [`ElasticTuning::revive_every`]
//! rounds the directory optimistically marks the fleet alive, the policy
//! hands the revived link a range again, and either it answers (rejoin)
//! or the takeover path absorbs the loss and it drops back out. No
//! separate probe protocol, no probe/true-traffic divergence.

use crate::cluster::{RemoteShardBackend, ShardAttempt};
use crate::engine::{
    ranges_tile, ReconcileReport, ShardBackend, ShardBackendError, ShardHealth, ShardRoundWork,
};
use crate::telemetry::{EventKind, EventRecord, SpanKind, Tracer};
use crate::transport::wire::ShardOutMsg;
use crate::transport::TrafficStats;

use super::directory::ShardDirectory;
use super::policy::RebalancePolicy;

/// Virtual shard ids for takeover slices start here — far above any real
/// link id, so a slice's identity can never collide with a link's own.
const TAKEOVER_SHARD_BASE: u32 = 1 << 24;

/// Latency sample for the directory: the shard-reported compute wall
/// normalized by the unit's span. Raw per-unit walls scale with the
/// assigned range, so feeding them to a latency-weighted policy would
/// punish a shard FOR holding a big range (and takeover slices — small
/// spans — would bias survivors fast); per-instance walls make the
/// EWMA an actual speed estimate that converges instead of oscillating.
fn per_instance_ns(wall_ns: u64, work: &ShardRoundWork) -> u64 {
    wall_ns / work.span().max(1) as u64
}

/// Control-plane tuning.
#[derive(Clone, Copy, Debug)]
pub struct ElasticTuning {
    /// EWMA smoothing factor for the latency estimate (weight of the
    /// newest sample), in (0, 1].
    pub ewma_alpha: f64,
    /// Offer dead links work again every this many rounds (0 = never —
    /// a lost shard stays parked forever). See the module notes on
    /// probe-by-offering.
    pub revive_every: u64,
}

impl Default for ElasticTuning {
    fn default() -> Self {
        ElasticTuning { ewma_alpha: 0.3, revive_every: 4 }
    }
}

/// The elastic control plane over a remote shard fleet.
pub struct ElasticController {
    inner: RemoteShardBackend,
    directory: ShardDirectory,
    policy: Box<dyn RebalancePolicy>,
    tuning: ElasticTuning,
    takeovers: u64,
    /// Next virtual shard id suffix — never reused, so a stale takeover
    /// placement on a server can never match later work.
    virt_next: u32,
    /// Flight recorder for takeover scopes (noop default; shared with the
    /// inner backend's frame/retry events via [`ShardBackend::set_tracer`]).
    tracer: Tracer,
}

impl ElasticController {
    pub fn new(inner: RemoteShardBackend, policy: Box<dyn RebalancePolicy>) -> Self {
        let tuning = ElasticTuning::default();
        let directory = ShardDirectory::new(inner.link_count(), tuning.ewma_alpha);
        ElasticController {
            inner,
            directory,
            policy,
            tuning,
            takeovers: 0,
            virt_next: 0,
            tracer: Tracer::noop(),
        }
    }

    pub fn with_tuning(mut self, tuning: ElasticTuning) -> Self {
        self.directory = ShardDirectory::new(self.inner.link_count(), tuning.ewma_alpha);
        self.tuning = tuning;
        self
    }

    pub fn directory(&self) -> &ShardDirectory {
        &self.directory
    }

    pub fn policy_label(&self) -> &'static str {
        self.policy.label()
    }

    /// Re-scatter one lost work unit's range across surviving links,
    /// looping as survivors themselves fail (each failed survivor is
    /// marked dead, shrinking the pool) until the range is covered or
    /// nobody is left — only then does the round fail with the loss the
    /// plain backend would have reported immediately.
    fn takeover(&mut self, lost: ShardRoundWork) -> Result<ShardOutMsg, ShardBackendError> {
        let (round, shard) = (lost.round(), lost.shard());
        let (lo, hi) = (lost.lo(), lost.lo() + lost.span());
        self.takeovers += 1;
        // Recovery scope + event: the count is the instance span being
        // re-scattered — sizes and ids only, per the telemetry trust rule.
        let _takeover_span = self.tracer.span(SpanKind::Recovery, "takeover", round, shard);
        self.tracer.record(
            EventRecord::new(EventKind::Takeover, round)
                .with_shard(shard)
                .with_count((hi - lo) as u64),
        );
        let mut missing: Vec<(u32, u32)> = vec![(lo, hi)];
        // (slice lo, output) pieces, stitched back together at the end.
        let mut pieces: Vec<(u32, ShardOutMsg)> = Vec::new();
        // (link, virtual id) placements to retire once the range is done.
        let mut placements: Vec<(usize, u32)> = Vec::new();
        while !missing.is_empty() {
            let survivors = self.directory.alive_links();
            if survivors.is_empty() {
                return Err(ShardBackendError::ShardLost {
                    shard,
                    attempts: self.inner.tuning().max_retries + 1,
                });
            }
            // Slice ONE missing range across the survivor pool per pass,
            // each slice under a fresh virtual identity on a DISTINCT
            // link — `run_attempts` wants at most one pending unit per
            // link (a second unit's in-flight reply would be discarded as
            // stale by the first's gather and cost spurious retries).
            // Later missing ranges (only possible after a survivor also
            // failed) wait for the next pass.
            let (mlo, mhi) = missing.remove(0);
            let span = (mhi - mlo) as usize;
            let cuts = crate::engine::shard_ranges(span, survivors.len().min(span));
            let mut batch: Vec<(usize, ShardRoundWork)> = Vec::new();
            for (k, (a, b)) in cuts.into_iter().enumerate() {
                let (slo, shi) = (mlo + a as u32, mlo + b as u32);
                let virt = TAKEOVER_SHARD_BASE + self.virt_next;
                self.virt_next += 1;
                let slice = match lost.slice(slo, shi, virt) {
                    Some(s) => s,
                    None => {
                        return Err(ShardBackendError::Merge {
                            shard,
                            detail: format!("takeover slice [{slo},{shi}) outside the lost range"),
                        })
                    }
                };
                placements.push((survivors[k], virt));
                batch.push((survivors[k], slice));
            }
            // Successes first, failures second: a link that lost its
            // slice this pass ends the pass dead. Every pass either
            // clears a missing range or shrinks the survivor pool, so
            // the loop terminates.
            let attempts = self.inner.run_attempts(batch)?;
            for a in &attempts {
                if let Some(out) = &a.out {
                    self.directory.record_success(a.link, per_instance_ns(out.wall_ns, &a.work));
                    self.directory.record_takeover(a.link);
                }
            }
            for a in attempts {
                match a.out {
                    Some(out) => pieces.push((a.work.lo(), out)),
                    None => {
                        // This survivor is down too: mark it and put its
                        // slice back on the missing list for the next
                        // (smaller) survivor pool.
                        self.directory.record_failure(a.link);
                        missing.push((a.work.lo(), a.work.lo() + a.work.span()));
                    }
                }
            }
        }
        // Placement hygiene: virtual ids are one-shot, drop them. Dead
        // links just skip (nothing to say to a link that isn't answering).
        for (link, virt) in placements {
            if self.directory.alive(link) {
                self.inner.retire(link, virt)?;
            }
        }
        // Stitch the slices back into the lost shard's output, in
        // instance order — the caller's merge sees a whole shard.
        pieces.sort_by_key(|&(slo, _)| slo);
        let mut estimates = Vec::with_capacity((hi - lo) as usize);
        let mut wall_ns = 0u64;
        let mut cursor = lo;
        for (slo, out) in pieces {
            if slo != cursor {
                return Err(ShardBackendError::Merge {
                    shard,
                    detail: format!("takeover slices leave a gap at instance {cursor}"),
                });
            }
            cursor += out.estimates.len() as u32;
            wall_ns = wall_ns.max(out.wall_ns);
            estimates.extend_from_slice(&out.estimates);
        }
        if cursor != hi {
            return Err(ShardBackendError::Merge {
                shard,
                detail: format!("takeover covered [{lo}, {cursor}) of [{lo}, {hi})"),
            });
        }
        Ok(ShardOutMsg { round, shard, wall_ns, estimates })
    }
}

impl ShardBackend for ElasticController {
    fn run_shards(
        &mut self,
        work: Vec<ShardRoundWork>,
    ) -> Result<Vec<ShardOutMsg>, ShardBackendError> {
        let batch: Vec<(usize, ShardRoundWork)> =
            work.into_iter().map(|w| (w.shard() as usize, w)).collect();
        let attempts: Vec<ShardAttempt> = self.inner.run_attempts(batch)?;
        let mut outs = Vec::with_capacity(attempts.len());
        let mut lost = Vec::new();
        for a in attempts {
            match a.out {
                Some(o) => {
                    self.directory.record_success(a.link, per_instance_ns(o.wall_ns, &a.work));
                    outs.push(o);
                }
                None => {
                    self.directory.record_failure(a.link);
                    lost.push(a.work);
                }
            }
        }
        for w in lost {
            let out = self.takeover(w)?;
            outs.push(out);
        }
        Ok(outs)
    }

    fn plan_ranges(&mut self, round: u64, default: &[(usize, usize)]) -> Vec<(usize, usize)> {
        if self.tuning.revive_every > 0 && round > 0 && round % self.tuning.revive_every == 0 {
            self.directory.revive_all();
        }
        let instances = default.last().map(|&(_, hi)| hi).unwrap_or(0);
        let ranges = self.policy.partition(instances, self.directory.health());
        if ranges.len() != default.len() || !ranges_tile(&ranges, instances) {
            // A malformed policy tiling must not fail the round — the
            // static layout is always safe.
            return default.to_vec();
        }
        ranges
    }

    fn health(&self) -> Vec<ShardHealth> {
        self.directory.snapshot()
    }

    fn take_traffic(&mut self) -> (TrafficStats, ReconcileReport) {
        self.inner.take_traffic()
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.inner.set_tracer(tracer.clone());
        self.tracer = tracer;
    }

    fn retries(&self) -> u64 {
        self.inner.retries()
    }

    fn takeovers(&self) -> u64 {
        self.takeovers
    }

    fn label(&self) -> &'static str {
        "elastic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterEngine, ClusterTuning};
    use crate::engine::{DerivedClientSeeds, Engine, EngineConfig, RoundInput};
    use crate::params::ProtocolPlan;
    use crate::transport::channel::{Channel, Loopback, SimNet, SimNetConfig};
    use crate::transport::wire::ShardPoolMsg;

    fn small_plan(n: usize) -> ProtocolPlan {
        ProtocolPlan::exact_secure_agg(n, 100, 8)
    }

    fn inputs_for(n: usize, d: usize) -> Vec<Vec<f64>> {
        (0..n)
            .map(|i| (0..d).map(|j| ((i * 7 + j * 13) % 100) as f64 / 100.0).collect())
            .collect()
    }

    /// An elastic cluster where `victim`'s inbound link goes silent after
    /// `after` delivered frames (and optionally heals after `recover`).
    fn elastic_cluster(
        cfg: &EngineConfig,
        seed: u64,
        victim: usize,
        after: u64,
        recover: Option<u64>,
        policy: Box<dyn RebalancePolicy>,
        tuning: ElasticTuning,
    ) -> ClusterEngine {
        let backend = RemoteShardBackend::over_channels(cfg, |s| {
            let down: Box<dyn Channel> = if s == victim {
                let mut c = SimNetConfig::new(5).with_silent_after(after);
                if let Some(r) = recover {
                    c = c.with_recover_after(r);
                }
                Box::new(SimNet::new(c))
            } else {
                Box::new(Loopback::new())
            };
            (down, Box::new(Loopback::new()) as _)
        })
        .with_tuning(ClusterTuning { max_retries: 1, ..ClusterTuning::default() });
        let controller = ElasticController::new(backend, policy).with_tuning(tuning);
        ClusterEngine::new(cfg.clone(), seed, Box::new(controller))
    }

    #[test]
    fn takeover_keeps_the_round_bit_identical() {
        // Shard 1 of 3 dies after its handshake; the elastic controller
        // re-scatters its range to shards 0 and 2 and the round completes
        // with estimates bit-identical to the healthy in-process run.
        let (n, d, seed) = (12usize, 9usize, 3u64);
        let inputs = inputs_for(n, d);
        let seeds = DerivedClientSeeds::new(seed);
        let cfg = EngineConfig::new(small_plan(n), d).with_shards(3);
        let mut engine = Engine::new(cfg.clone(), seed);
        let want = engine.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
        let mut cluster = elastic_cluster(
            &cfg,
            seed,
            1,
            1, // assign delivered, work and every resend vanish
            None,
            Box::new(crate::control::EvenSplit),
            ElasticTuning { revive_every: 0, ..Default::default() },
        );
        let got = cluster.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
        assert_eq!(got.estimates, want.estimates, "takeover must not change the sums");
        assert_eq!(cluster.shard_takeovers(), 1);
        assert_eq!(cluster.metrics().counter("cluster.takeovers").get(), 1);
        let health = cluster.shard_health();
        assert!(!health[1].alive, "victim marked dead");
        assert_eq!(health[1].failures, 1);
        assert!(
            health[0].takeovers_absorbed + health[2].takeovers_absorbed >= 2,
            "both survivors absorbed a slice of the 3-instance range"
        );
    }

    #[test]
    fn next_round_parks_the_dead_shard_and_stays_identical() {
        // After a takeover round, the policy re-ranges: the dead shard's
        // link gets an empty range and the round runs with no retries at
        // all — still bit-identical to the engine.
        let (n, d, seed) = (10usize, 8usize, 11u64);
        let inputs = inputs_for(n, d);
        let seeds = DerivedClientSeeds::new(seed);
        let cfg = EngineConfig::new(small_plan(n), d).with_shards(4);
        let mut engine = Engine::new(cfg.clone(), seed);
        let mut cluster = elastic_cluster(
            &cfg,
            seed,
            2,
            1,
            None,
            Box::new(crate::control::EvenSplit),
            ElasticTuning { revive_every: 0, ..Default::default() },
        );
        for round in 0..3 {
            let want = engine.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
            let got = cluster.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
            assert_eq!(got.estimates, want.estimates, "round {round}");
        }
        assert_eq!(cluster.shard_takeovers(), 1, "only the death round needed takeover");
        let health = cluster.shard_health();
        assert!(!health[2].alive);
        assert_eq!(health[2].failures, 1, "a parked shard is never offered work to lose");
    }

    #[test]
    fn flappy_link_rejoins_after_revival_offer() {
        // The victim's link heals while parked; the periodic revival offer
        // hands it a range again and it rejoins — takeover-then-rejoin.
        let (n, d, seed) = (10usize, 8usize, 17u64);
        let inputs = inputs_for(n, d);
        let seeds = DerivedClientSeeds::new(seed);
        let cfg = EngineConfig::new(small_plan(n), d).with_shards(2);
        let mut engine = Engine::new(cfg.clone(), seed);
        // Victim delivers its round-0 handshake+work (2 frames), loses
        // everything in (2, 6], then heals — in time for the round-4
        // revival offer (sends 3–6 are the round-1 loss and the round-2
        // re-offer, both silenced).
        let mut cluster = elastic_cluster(
            &cfg,
            seed,
            1,
            2,
            Some(6),
            Box::new(crate::control::EvenSplit),
            ElasticTuning { revive_every: 2, ..Default::default() },
        );
        let mut rejoined = false;
        for round in 0..6 {
            let want = engine.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
            let got = cluster.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap();
            assert_eq!(got.estimates, want.estimates, "round {round}");
            let h = cluster.shard_health();
            if round >= 1 && h[1].alive && h[1].rounds_ok >= 2 {
                rejoined = true;
            }
        }
        assert!(rejoined, "healed link must rejoin via the revival offer");
        assert!(cluster.shard_takeovers() >= 1, "the flap must have cost a takeover");
    }

    #[test]
    fn takeover_with_no_survivors_is_shard_lost() {
        let (n, d, seed) = (8usize, 4usize, 7u64);
        let inputs = inputs_for(n, d);
        let seeds = DerivedClientSeeds::new(seed);
        let cfg = EngineConfig::new(small_plan(n), d).with_shards(2);
        // BOTH links go silent after their handshakes.
        let backend = RemoteShardBackend::over_channels(&cfg, |_| {
            let down: Box<dyn Channel> =
                Box::new(SimNet::new(SimNetConfig::new(9).with_silent_after(1)));
            (down, Box::new(Loopback::new()) as _)
        })
        .with_tuning(ClusterTuning { max_retries: 1, ..ClusterTuning::default() });
        let controller =
            ElasticController::new(backend, Box::new(crate::control::EvenSplit));
        let mut cluster = ClusterEngine::new(cfg, seed, Box::new(controller));
        let err = cluster.run_round(&RoundInput::Vectors(&inputs), &seeds).unwrap_err();
        assert!(
            matches!(err, ShardBackendError::ShardLost { .. }),
            "a fleet with no survivors still fails the round: {err:?}"
        );
        assert_eq!(cluster.next_round(), 0, "failed round id is not consumed");
    }

    #[test]
    fn takeover_slices_pool_work_too() {
        // Streaming-path takeover at the work-unit level: a lost pool unit
        // sliced across two survivors reproduces its estimates exactly.
        let (n, d, seed) = (12usize, 6usize, 21u64);
        let inputs = inputs_for(n, d);
        let seeds = DerivedClientSeeds::new(seed);
        let cfg = EngineConfig::new(small_plan(n), d).with_shards(3);
        let mut engine = Engine::new(cfg.clone(), seed);
        let m = cfg.plan.num_messages;
        let who: Vec<usize> = (0..n).filter(|i| i % 4 != 1).collect();
        let mut pools = vec![Vec::new(); d];
        for &i in &who {
            let shares = engine
                .encode_client_shares(0, i as u32, &RoundInput::Vectors(&inputs), &seeds)
                .unwrap();
            for (j, pool) in pools.iter_mut().enumerate() {
                pool.extend_from_slice(&shares[j * m..(j + 1) * m]);
            }
        }
        let want = engine.run_round_streaming(&pools, who.len()).unwrap();
        let mut cluster = elastic_cluster(
            &cfg,
            seed,
            0, // the FIRST shard dies this time
            1,
            None,
            Box::new(crate::control::EvenSplit),
            ElasticTuning { revive_every: 0, ..Default::default() },
        );
        let got = cluster.run_round_streaming(&pools, who.len()).unwrap();
        assert_eq!(got.estimates, want.estimates, "streaming takeover must be bit-identical");
        assert_eq!(cluster.shard_takeovers(), 1);
    }

    #[test]
    fn work_slice_shapes_are_exact() {
        let w = ShardRoundWork::Pool(ShardPoolMsg {
            round: 2,
            shard: 1,
            lo: 4,
            span: 3,
            participants: 2,
            round_seed: 9,
            pool: (0..3 * 2 * 4).map(|x| x as u64).collect(), // m = 4
        });
        let s = w.slice(5, 7, 77).unwrap();
        assert_eq!(s.shard(), 77);
        assert_eq!((s.lo(), s.span()), (5, 2));
        let ShardRoundWork::Pool(p) = &s else { panic!("pool slice") };
        assert_eq!(p.pool, ((2 * 4)..(3 * 2 * 4)).map(|x| x as u64).collect::<Vec<_>>());
        assert!(w.slice(3, 5, 0).is_none(), "below the unit's range");
        assert!(w.slice(5, 8, 0).is_none(), "beyond the unit's range");
        assert!(w.slice(5, 5, 0).is_none(), "empty");
    }
}
