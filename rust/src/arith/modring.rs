//! The message ring Z_N.
//!
//! All protocol messages are residues mod an odd `u64` modulus N. The ring
//! is a small value type passed around by copy; every operation is
//! division-free on the hot path except the initial reduction (one `%` per
//! *foreign* value entering the ring — internal ops use conditional
//! subtract, matching the L1 kernel's compare+select idiom).

use crate::rng::Rng;

/// Arithmetic over Z_N for odd N (Algorithm 1/2's message space).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModRing {
    modulus: u64,
}

impl ModRing {
    /// Create a ring; panics if `modulus` is 0 or even (Algorithm 2 requires
    /// odd N so that the analyzer's range decision is unambiguous).
    pub fn new(modulus: u64) -> Self {
        assert!(modulus > 0, "modulus must be positive");
        assert!(modulus % 2 == 1, "Algorithm 2 requires odd N, got {modulus}");
        ModRing { modulus }
    }

    #[inline(always)]
    pub fn modulus(&self) -> u64 {
        self.modulus
    }

    /// Reduce an arbitrary u64 into the ring.
    #[inline(always)]
    pub fn reduce(&self, x: u64) -> u64 {
        if x < self.modulus {
            x
        } else {
            x % self.modulus
        }
    }

    /// Reduce an u128 (e.g. a large accumulator) into the ring.
    #[inline(always)]
    pub fn reduce_u128(&self, x: u128) -> u64 {
        (x % self.modulus as u128) as u64
    }

    /// a + b mod N for a, b already in the ring — division-free.
    #[inline(always)]
    pub fn add(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.modulus && b < self.modulus);
        let (s, carry) = a.overflowing_add(b);
        // a, b < N <= 2^64-1: sum fits in u64 unless N > 2^63; handle both.
        if carry || s >= self.modulus {
            s.wrapping_sub(self.modulus)
        } else {
            s
        }
    }

    /// a - b mod N for a, b already in the ring — division-free.
    #[inline(always)]
    pub fn sub(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.modulus && b < self.modulus);
        if a >= b {
            a - b
        } else {
            a.wrapping_sub(b).wrapping_add(self.modulus)
        }
    }

    /// a * b mod N via u128 widening.
    #[inline(always)]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        ((a as u128 * b as u128) % self.modulus as u128) as u64
    }

    /// -a mod N.
    #[inline(always)]
    pub fn neg(&self, a: u64) -> u64 {
        debug_assert!(a < self.modulus);
        if a == 0 {
            0
        } else {
            self.modulus - a
        }
    }

    /// Map a signed integer (e.g. discrete Laplace noise) into the ring.
    #[inline]
    pub fn from_i64(&self, x: i64) -> u64 {
        let m = self.modulus as i128;
        let r = (x as i128).rem_euclid(m);
        r as u64
    }

    /// Interpret a residue as the *centered* representative in
    /// `(-(N-1)/2 ..= (N-1)/2)` — the analyzer's signed read-back.
    #[inline]
    pub fn to_centered(&self, x: u64) -> i64 {
        debug_assert!(x < self.modulus);
        let half = self.modulus / 2; // N odd => (N-1)/2
        if x <= half {
            x as i64
        } else {
            -((self.modulus - x) as i64)
        }
    }

    /// Uniform draw from Z_N (unbiased; Lemire rejection via [`Rng`]).
    #[inline]
    pub fn sample<R: Rng>(&self, rng: &mut R) -> u64 {
        rng.gen_range(self.modulus)
    }

    /// Sum of a slice of in-ring values, division-free inner loop.
    pub fn sum(&self, xs: &[u64]) -> u64 {
        let mut acc = 0u64;
        for &x in xs {
            acc = self.add(acc, x);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{SeedableRng, SplitMix64};
    use crate::util::proptest_lite::{forall, Gen};

    #[test]
    #[should_panic(expected = "odd")]
    fn even_modulus_rejected() {
        ModRing::new(10);
    }

    #[test]
    fn add_sub_roundtrip() {
        let r = ModRing::new(101);
        for a in 0..101 {
            for b in 0..101 {
                let s = r.add(a, b);
                assert_eq!(r.sub(s, b), a);
                assert_eq!((a + b) % 101, s);
            }
        }
    }

    #[test]
    fn add_near_u64_max() {
        // N just below 2^64: the carry path must be taken.
        let n = u64::MAX; // 2^64-1 is odd
        let r = ModRing::new(n);
        let a = n - 1;
        let b = n - 2;
        // (a + b) mod n = (2n - 3) mod n = n - 3
        assert_eq!(r.add(a, b), n - 3);
    }

    #[test]
    fn mul_matches_u128() {
        let r = ModRing::new(1_000_000_007);
        let mut rng = SplitMix64::seed_from_u64(1);
        for _ in 0..1000 {
            let a = r.sample(&mut rng);
            let b = r.sample(&mut rng);
            assert_eq!(r.mul(a, b), ((a as u128 * b as u128) % 1_000_000_007u128) as u64);
        }
    }

    #[test]
    fn from_i64_negative() {
        let r = ModRing::new(7);
        assert_eq!(r.from_i64(-1), 6);
        assert_eq!(r.from_i64(-7), 0);
        assert_eq!(r.from_i64(-8), 6);
        assert_eq!(r.from_i64(13), 6);
        // 2^63 ≡ 1 (mod 7), so i64::MIN = -2^63 ≡ -1 ≡ 6 (mod 7).
        assert_eq!(r.from_i64(i64::MIN), 6);
    }

    #[test]
    fn centered_representatives() {
        let r = ModRing::new(7);
        assert_eq!(r.to_centered(0), 0);
        assert_eq!(r.to_centered(3), 3);
        assert_eq!(r.to_centered(4), -3);
        assert_eq!(r.to_centered(6), -1);
        // round trip through from_i64
        for t in -3..=3i64 {
            assert_eq!(r.to_centered(r.from_i64(t)), t);
        }
    }

    #[test]
    fn prop_sum_matches_u128_reference() {
        forall("ring sum", 200, |g: &mut Gen| {
            let n = g.odd_u64(3, 1 << 40);
            let r = ModRing::new(n);
            let len = g.usize_in(0, 64);
            let xs: Vec<u64> = (0..len).map(|_| g.u64_below(n)).collect();
            let want = (xs.iter().map(|&x| x as u128).sum::<u128>() % n as u128) as u64;
            assert_eq!(r.sum(&xs), want);
        });
    }

    #[test]
    fn prop_neg_is_additive_inverse() {
        forall("neg inverse", 200, |g: &mut Gen| {
            let n = g.odd_u64(3, u64::MAX);
            let r = ModRing::new(n);
            let a = g.u64_below(n);
            assert_eq!(r.add(a, r.neg(a)), 0);
        });
    }

    #[test]
    fn sample_is_in_range_and_covers() {
        let r = ModRing::new(5);
        let mut rng = SplitMix64::seed_from_u64(3);
        let mut seen = [false; 5];
        for _ in 0..200 {
            let v = r.sample(&mut rng);
            assert!(v < 5);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
