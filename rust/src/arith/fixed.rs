//! Fixed-point codecs: the paper's discretization `x̄ = ⌊x·k⌋`.
//!
//! Two codecs:
//! * [`FixedCodec`] — the paper's unit-interval codec for x ∈ [0, 1]
//!   (§2.1: inputs are rounded to the nearest lower multiple of 1/k).
//! * [`SymmetricCodec`] — the FL driver's codec for clipped gradient
//!   coordinates x ∈ [-c, c], mapped affinely into [0, 1] before
//!   quantization so aggregation error stays the paper's n/k bound
//!   (DESIGN.md §3, FL row).

/// Quantizer for x ∈ [0, 1] with scale k: encode(x) = ⌊x·k⌋ ∈ {0, …, k}.
#[derive(Clone, Copy, Debug)]
pub struct FixedCodec {
    scale: u64,
}

impl FixedCodec {
    /// `scale` is the paper's k; must be ≥ 1.
    pub fn new(scale: u64) -> Self {
        assert!(scale >= 1, "scale k must be >= 1");
        FixedCodec { scale }
    }

    pub fn scale(&self) -> u64 {
        self.scale
    }

    /// ⌊x·k⌋ with clamping of x into [0, 1] (protocol precondition).
    pub fn encode(&self, x: f64) -> u64 {
        let x = x.clamp(0.0, 1.0);
        let v = (x * self.scale as f64).floor() as u64;
        v.min(self.scale) // x = 1.0 maps to k
    }

    /// Decode an aggregated integer sum back to the real scale: z̄/k.
    pub fn decode_sum(&self, zbar: u64) -> f64 {
        zbar as f64 / self.scale as f64
    }

    /// Worst-case per-user rounding error: 1/k.
    pub fn per_user_error(&self) -> f64 {
        1.0 / self.scale as f64
    }
}

/// Affine codec for x ∈ [-c, c]: maps to u = (x + c) / (2c) ∈ [0,1], then
/// quantizes with [`FixedCodec`]. Decoding an aggregate of n users undoes
/// the affine shift: sum(x) = 2c·(sum(u)) − n·c.
#[derive(Clone, Copy, Debug)]
pub struct SymmetricCodec {
    inner: FixedCodec,
    clip: f64,
}

impl SymmetricCodec {
    pub fn new(scale: u64, clip: f64) -> Self {
        assert!(clip > 0.0);
        SymmetricCodec { inner: FixedCodec::new(scale), clip }
    }

    pub fn scale(&self) -> u64 {
        self.inner.scale()
    }

    pub fn clip(&self) -> f64 {
        self.clip
    }

    /// Quantize one clipped coordinate.
    pub fn encode(&self, x: f64) -> u64 {
        let u = (x.clamp(-self.clip, self.clip) + self.clip) / (2.0 * self.clip);
        self.inner.encode(u)
    }

    /// Decode the aggregated integer sum of `n` users' coordinates.
    pub fn decode_sum(&self, zbar: u64, n: usize) -> f64 {
        2.0 * self.clip * self.inner.decode_sum(zbar) - n as f64 * self.clip
    }

    /// Worst-case aggregate quantization error for n users: 2c·n/k.
    pub fn aggregate_error_bound(&self, n: usize) -> f64 {
        2.0 * self.clip * n as f64 / self.scale() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{forall, Gen};

    #[test]
    fn encode_bounds() {
        let c = FixedCodec::new(10);
        assert_eq!(c.encode(0.0), 0);
        assert_eq!(c.encode(1.0), 10);
        assert_eq!(c.encode(0.55), 5);
        assert_eq!(c.encode(-3.0), 0); // clamped
        assert_eq!(c.encode(7.0), 10); // clamped
    }

    #[test]
    fn decode_inverts_up_to_rounding() {
        let c = FixedCodec::new(1 << 20);
        for &x in &[0.0, 0.1, 0.25, 0.5, 0.9999, 1.0] {
            let err = (c.decode_sum(c.encode(x)) - x).abs();
            assert!(err <= c.per_user_error(), "x={x} err={err}");
        }
    }

    #[test]
    fn prop_sum_error_bounded_by_n_over_k() {
        forall("fixed sum error", 100, |g: &mut Gen| {
            let k = 1u64 << g.usize_in(8, 24);
            let c = FixedCodec::new(k);
            let n = g.usize_in(1, 200);
            let xs: Vec<f64> = (0..n).map(|_| g.f64_unit()).collect();
            let truth: f64 = xs.iter().sum();
            let agg: u64 = xs.iter().map(|&x| c.encode(x)).sum();
            let err = (c.decode_sum(agg) - truth).abs();
            assert!(err <= n as f64 / k as f64 + 1e-9, "err={err} n={n} k={k}");
        });
    }

    #[test]
    fn symmetric_roundtrip() {
        let c = SymmetricCodec::new(1 << 16, 1.0);
        // single user (n=1)
        for &x in &[-1.0, -0.5, 0.0, 0.3, 1.0] {
            let err = (c.decode_sum(c.encode(x), 1) - x).abs();
            assert!(err <= 2.0 / (1 << 16) as f64, "x={x} err={err}");
        }
    }

    #[test]
    fn prop_symmetric_aggregate_error() {
        forall("symmetric agg error", 100, |g: &mut Gen| {
            let k = 1u64 << g.usize_in(10, 20);
            let clip = 0.5 + g.f64_unit();
            let c = SymmetricCodec::new(k, clip);
            let n = g.usize_in(1, 100);
            let xs: Vec<f64> = (0..n).map(|_| (g.f64_unit() * 2.0 - 1.0) * clip).collect();
            let truth: f64 = xs.iter().sum();
            let agg: u64 = xs.iter().map(|&x| c.encode(x)).sum();
            let err = (c.decode_sum(agg, n) - truth).abs();
            assert!(err <= c.aggregate_error_bound(n) + 1e-9, "err={err}");
        });
    }
}
