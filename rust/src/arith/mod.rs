//! Arithmetic substrate: the message ring Z_N and fixed-point codecs.
//!
//! Algorithms 1–2 operate over Z_N for an odd modulus N > 3nk; Theorem 1/2
//! parameter choices can push N beyond 2^32, so [`modring::ModRing`] keeps a
//! `u64` modulus with `u128` widening on every multiply/accumulate. The
//! Pallas kernel path uses a restricted int32-safe profile (N < 2^30); the
//! planner decides which profile a given (n, ε, δ) fits.

#![deny(clippy::redundant_clone)]

pub mod fixed;
pub mod modring;

/// Returns the first odd integer strictly greater than `x` (the paper's
/// "N = first odd integer larger than 3kn + 10/δ + 10/ε").
pub fn next_odd_above(x: f64) -> u64 {
    let mut v = x.floor() as u64 + 1;
    if v % 2 == 0 {
        v += 1;
    }
    v
}

/// ceil(log2(x)) for x >= 1 — message-size accounting (Fig. 1 columns).
pub fn ceil_log2(x: u64) -> u32 {
    debug_assert!(x >= 1);
    64 - (x - 1).leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_odd() {
        assert_eq!(next_odd_above(4.0), 5);
        assert_eq!(next_odd_above(5.0), 7);
        assert_eq!(next_odd_above(5.5), 7);
        assert_eq!(next_odd_above(6.0), 7);
        assert_eq!(next_odd_above(0.2), 1);
    }

    #[test]
    fn log2_ceil() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(1 << 40), 40);
        assert_eq!(ceil_log2((1 << 40) + 1), 41);
    }
}
