//! CLUSTER: full-round throughput across backend × shard count — what a
//! round costs when shards leave the process.
//!
//!     cargo bench --bench cluster_round
//!
//! Backends: `inprocess` (local threads, no wire — the floor),
//! `loopback` (full wire codec through in-memory channels — the
//! serialization cost in isolation) and `tcp` (shard servers on
//! localhost sockets — serialization + syscalls + real scatter/gather).
//! Every stack is built declaratively by `AggregatorBuilder` and timed
//! through the `Aggregator` trait — ONE code path for every backend; the
//! only per-backend line is the topology. Every case is gate-checked
//! bit-identical to the in-process `Engine` before the timer starts.
//! Results land in BENCH_cluster_round.json (benchkit schema, `shards`
//! axis populated), seeding the cluster bench trajectory.

use std::time::Duration;

use cloak_agg::aggregator::{Aggregator, AggregatorBuilder};
use cloak_agg::cluster::{cluster_layout, ServeOpts, TcpShardHost};
use cloak_agg::engine::{DerivedClientSeeds, Engine, EngineConfig, RoundInput};
use cloak_agg::params::ProtocolPlan;
use cloak_agg::transport::channel::Loopback;
use cloak_agg::transport::{
    contribute_batch_wire_len, contribute_wire_len, send_cohort, send_cohort_batched,
    StreamConfig, StreamingRound,
};
use cloak_agg::util::benchkit::Bench;

fn main() {
    let (n, d, seed) = (96usize, 32usize, 9u64);
    let plan = ProtocolPlan::exact_secure_agg(n, 100, 8);
    let m = plan.num_messages;
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..d).map(|j| ((i * 3 + j * 11) % 100) as f64 / 100.0).collect())
        .collect();
    let seeds = DerivedClientSeeds::new(seed);

    let mut b = Bench::new("cluster_round").with_window(
        Duration::from_millis(50),
        Duration::from_millis(250),
        5,
    );

    for backend_name in ["inprocess", "loopback", "tcp"] {
        for s in [1usize, 2, 4] {
            let cfg = EngineConfig::new(plan.clone(), d).with_shards(s);

            // Gate: one cluster round must reproduce the in-process engine
            // bit-exactly before this case's numbers mean anything.
            let mut reference = Engine::new(cfg.clone(), seed);
            let want = reference
                .run_round(&RoundInput::Vectors(&inputs), &seeds)
                .expect("reference round")
                .estimates;

            // TCP is the only topology with real hosts to spawn; the
            // stack construction itself is one builder line per backend.
            let hosts: Vec<TcpShardHost> = if backend_name == "tcp" {
                (0..cluster_layout(&cfg).0)
                    .map(|_| {
                        TcpShardHost::spawn(cfg.clone(), 0, ServeOpts::default())
                            .expect("bind shard host")
                    })
                    .collect()
            } else {
                Vec::new()
            };
            let builder = AggregatorBuilder::new(cfg.clone(), seed);
            let mut cluster: Box<dyn Aggregator> = match backend_name {
                "inprocess" => builder.in_process(),
                "loopback" => builder.loopback(),
                _ => builder.tcp(hosts.iter().map(|h| h.addr().to_string()).collect()),
            }
            .build()
            .expect("build stack");

            let gate = cluster
                .run_round(&RoundInput::Vectors(&inputs), &seeds)
                .expect("gate round");
            assert_eq!(gate.estimates, want, "backend={backend_name} S={s} diverged");
            assert_eq!(cluster.backend_label(), backend_name);

            let name = format!("round n={n} d={d} backend={backend_name} S={s}");
            b.run_sharded(&name, (n * d * m) as f64, s, || {
                cluster
                    .run_round(&RoundInput::Vectors(&inputs), &seeds)
                    .expect("cluster round")
                    .estimates[0]
            });
            drop(cluster);
            for h in hosts {
                h.shutdown();
            }
        }
    }

    // Batched-wire sweep: the same streamed cohort as per-client
    // Contribute frames (batch=1) vs ContributeBatch coalescing — frames
    // and bytes per round drop with batch size while the estimates stay
    // bit-identical to the per-client wire (gate-checked per case).
    {
        let per_client = d * m;
        let cfg = EngineConfig::new(plan.clone(), d).with_shards(2);
        let mut reference = Engine::new(cfg.clone(), seed);
        let mut refch = Loopback::new();
        send_cohort(&reference, &seeds, &RoundInput::Vectors(&inputs), &vec![false; n], &mut refch)
            .expect("reference cohort");
        let want = StreamingRound::drive(&mut reference, &mut refch, &StreamConfig::new(n))
            .expect("reference streamed round");
        for batch in [1usize, 8, 32] {
            let mut engine = Engine::new(cfg.clone(), seed);
            let mut ch = Loopback::new();
            send_cohort_batched(
                &engine,
                &seeds,
                &RoundInput::Vectors(&inputs),
                &vec![false; n],
                &mut ch,
                batch,
            )
            .expect("batched cohort");
            let frames = ch.pending();
            let out = StreamingRound::drive(&mut engine, &mut ch, &StreamConfig::new(n))
                .expect("batched streamed round");
            assert_eq!(
                out.result.estimates, want.result.estimates,
                "wire-batch={batch} diverged from per-client frames"
            );
            let bytes = if batch <= 1 {
                n * contribute_wire_len(per_client)
            } else {
                let rem = n % batch;
                (n / batch) * contribute_batch_wire_len(batch, per_client)
                    + if rem > 0 { contribute_batch_wire_len(rem, per_client) } else { 0 }
            };
            println!(
                "wire-batch={batch}: {frames} frames/round, {:.1} bytes/user",
                bytes as f64 / n as f64
            );
            let name = format!("streamed round n={n} d={d} wire-batch={batch}");
            b.run_items(&name, (n * per_client) as f64, || {
                let mut ch = Loopback::new();
                send_cohort_batched(
                    &engine,
                    &seeds,
                    &RoundInput::Vectors(&inputs),
                    &vec![false; n],
                    &mut ch,
                    batch,
                )
                .expect("cohort");
                StreamingRound::drive(&mut engine, &mut ch, &StreamConfig::new(n))
                    .expect("streamed round")
                    .result
                    .estimates[0]
            });
        }
    }

    b.report();
    b.write_json("BENCH_cluster_round.json").expect("write BENCH_cluster_round.json");
    println!("\nwrote BENCH_cluster_round.json");
}
