//! THM2: the zero-noise regime — worst-case error 2^−m′ (i.e. pure
//! discretization n/k) and exactness of the modular readout.
//!
//!     cargo bench --bench thm2_sum_preserving
//!
//! Sweeps the message count m and the scale k: the analyzer recovers the
//! discretized sum EXACTLY for every m ≥ 4 (the error column is entirely
//! the rounding term, which halves as k doubles — Theorem 2's 2^−m with
//! m = log2 k in the paper's normalization).

use cloak_agg::analyzer::Analyzer;
use cloak_agg::encoder::CloakEncoder;
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{ChaCha20Rng, Rng, SeedableRng, SplitMix64};
use cloak_agg::shuffler::{FisherYates, Shuffler};

fn run_once(n: usize, k: u64, m: usize, seed: u64) -> (f64, f64) {
    let modulus = {
        let v = 3 * n as u64 * k + 10_001;
        if v % 2 == 0 {
            v + 1
        } else {
            v
        }
    };
    let enc = CloakEncoder::new(modulus, k, m);
    let ana = Analyzer::new(modulus, k, n);
    let mut data_rng = SplitMix64::seed_from_u64(seed);
    let xs: Vec<f64> = (0..n).map(|_| data_rng.gen_f64()).collect();
    let truth: f64 = xs.iter().sum();
    let mut rng = ChaCha20Rng::seed_from_u64(seed ^ 0xABCD);
    let mut messages = Vec::with_capacity(n * m);
    for &x in &xs {
        messages.extend(enc.encode_scalar(x, &mut rng));
    }
    let mut fy = FisherYates::new(ChaCha20Rng::seed_from_u64(seed ^ 0x55));
    fy.shuffle(&mut messages);
    let est = ana.analyze(&messages);
    // error against the *discretized* truth must be 0; against the real
    // truth it is bounded by n/k.
    let truth_bar: u64 = xs.iter().map(|&x| (x * k as f64).floor() as u64).sum();
    let exact_err = (est - truth_bar as f64 / k as f64).abs();
    let real_err = (est - truth).abs();
    (exact_err, real_err)
}

fn main() {
    let n = 2_000;
    let mut table = Table::new(
        "Thm 2 — zero-noise exactness (n=2000)",
        &["k", "m", "err vs discretized", "err vs real", "bound n/k"],
    );
    let mut halving: Vec<f64> = Vec::new();
    for &(k, m) in &[
        (1u64 << 8, 4usize),
        (1 << 10, 8),
        (1 << 12, 16),
        (1 << 14, 32),
        (1 << 16, 64),
        (1 << 20, 128),
    ] {
        let (exact_err, real_err) = run_once(n, k, m, 42 + m as u64);
        assert!(exact_err < 1e-9, "modular readout must be exact (k={k}, m={m})");
        assert!(real_err <= n as f64 / k as f64 + 1e-9, "rounding bound violated");
        halving.push(real_err);
        table.row(&[
            k.to_string(),
            m.to_string(),
            fmt_f(exact_err),
            fmt_f(real_err),
            fmt_f(n as f64 / k as f64),
        ]);
    }
    println!("{}", table.emit("thm2_sum_preserving.txt"));
    // error decays ~2^-log2(k): across the sweep (k × 2^12) it must shrink
    // by ≥ 2^8 (rounding is a random variable; give slack)
    let shrink = halving[0] / halving.last().unwrap().max(1e-12);
    println!("rounding error shrink over sweep: ×{shrink:.0} (≥256 expected)");
    assert!(shrink > 256.0, "2^-m decay: {shrink}");
    println!("thm2_sum_preserving: shape OK");
}
