//! LEM12-13: collusion resilience — privacy of the honest subset when up
//! to 90% of users reveal their messages to the server.
//!
//!     cargo bench --bench collusion
//!
//! For coalition fractions {0, 0.3, 0.6, 0.9}: (a) the total estimate
//! stays exact; (b) the honest-pair share unions stay γ-smooth (the
//! quantity Lemma 3 needs, now over the honest subset only); (c) the
//! round wall-clock is unchanged — collusion costs nothing operationally.

use cloak_agg::arith::modring::ModRing;
use cloak_agg::coordinator::{honest_residual_sum, Coordinator, CoordinatorConfig};
use cloak_agg::params::{NeighborNotion, ProtocolPlan};
use cloak_agg::privacy::smoothness::measure;
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};

fn main() {
    let n = 40usize;
    let scale = 100u64;
    let modulus = {
        let v = 3 * n as u64 * scale + 101;
        if v % 2 == 0 {
            v + 1
        } else {
            v
        }
    };
    let m = 12usize;
    let plan =
        ProtocolPlan::custom(n, 1.0, 1e-6, NeighborNotion::SumPreserving, modulus, scale, m);
    let ring = ModRing::new(modulus);

    let mut rng = SplitMix64::seed_from_u64(9);
    let xs: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
    let truth_bar: u64 = xs.iter().map(|&x| (x * scale as f64).floor() as u64).sum();

    let mut table = Table::new(
        "Lemma 12/13 — collusion sweep (n=40, sum-preserving regime)",
        &["coalition", "estimate exact", "residual = Σ honest (allowed)", "honest-pair gamma", "round secs"],
    );
    let mut gammas = Vec::new();
    for frac in [0.0f64, 0.3, 0.6, 0.9] {
        let c = (n as f64 * frac) as usize;
        let mut coord = Coordinator::new(CoordinatorConfig::new(plan.clone(), 1), 50 + c as u64);
        coord.registry_mut().mark_colluding(&(0..c as u32).collect::<Vec<_>>());
        let inputs: Vec<Vec<f64>> = xs.iter().map(|&x| vec![x]).collect();
        let (result, views) = coord.run_round_with_views(&inputs).unwrap();

        let exact = (result.estimates[0] - truth_bar as f64 / scale as f64).abs() < 1e-9;
        assert!(exact, "collusion must not corrupt the aggregate");

        let total_raw =
            views.iter().fold(0u64, |acc, v| ring.add(acc, ring.sum(&v.shares)));
        let residual = honest_residual_sum(ring, total_raw, &views[..c]);
        let want: u64 =
            xs[c..].iter().map(|&x| (x * scale as f64).floor() as u64).sum();
        assert_eq!(residual, ring.reduce(want), "residual algebra");

        // γ-smoothness of an honest pair's unioned shares, averaged
        let mut g_acc = 0.0;
        let pairs = 3.min((n - c) / 2).max(1);
        for pi in 0..pairs {
            let a = &views[c + 2 * pi];
            let b = &views[c + 2 * pi + 1];
            let mut e = a.shares.clone();
            e.extend(b.shares.iter().copied());
            g_acc += measure(&e, modulus).gamma;
        }
        let gamma = g_acc / pairs as f64;
        gammas.push(gamma);
        table.row(&[
            format!("{:.0}%", frac * 100.0),
            exact.to_string(),
            residual.to_string(),
            fmt_f(gamma),
            format!("{:.4}", result.wall_seconds),
        ]);
    }
    println!("{}", table.emit("collusion.txt"));
    // honest-pair smoothness must not degrade as the coalition grows:
    // the γ of a pair is a property of *their own* fresh randomness.
    let max_g = gammas.iter().cloned().fold(0.0, f64::max);
    let min_g = gammas.iter().cloned().fold(f64::MAX, f64::min);
    println!("gamma across coalitions: [{min_g:.3}, {max_g:.3}] — flat, as Lemma 12 predicts");
    assert!(max_g < 3.0 * min_g.max(0.05), "smoothness must not degrade with collusion");
    println!("collusion: shape OK");
}
