//! PERF: the L3 encoder/analyzer hot paths — share generation, modular
//! reduction, shuffle — measured with the benchkit harness.
//!
//!     cargo bench --bench encoder_hotpath
//!
//! These are the numbers EXPERIMENTS.md §Perf tracks across optimization
//! iterations: shares/s for the scalar and vector encoders, ChaCha
//! keystream throughput (the encoder's roofline), Fisher–Yates and
//! mod-sum throughput.

use cloak_agg::analyzer::Analyzer;
use cloak_agg::arith::modring::ModRing;
use cloak_agg::encoder::CloakEncoder;
use cloak_agg::engine::{DerivedClientSeeds, Engine, EngineConfig, RoundInput};
use cloak_agg::params::ProtocolPlan;
use cloak_agg::rng::{uniform::fill_uniform, ChaCha20Rng, Rng, SeedableRng};
use cloak_agg::shuffler::{FisherYates, Shuffler};
use cloak_agg::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("encoder_hotpath");
    let modulus = 159_769_600_000_001u64; // faithful Thm-1 modulus at n=1e5
    let m = 64usize;

    // ChaCha20 keystream roofline: u64s/s
    {
        let mut rng = ChaCha20Rng::seed_from_u64(1);
        let mut buf = vec![0u64; 4096];
        b.run_items("chacha20 keystream (4096 u64)", 4096.0, || {
            for slot in buf.iter_mut() {
                *slot = rng.next_u64();
            }
            buf[0]
        });
    }

    // batched uniform sampling over Z_N
    {
        let mut rng = ChaCha20Rng::seed_from_u64(2);
        let mut buf = vec![0u64; 4096];
        b.run_items("fill_uniform Z_N (4096)", 4096.0, || {
            fill_uniform(&mut rng, modulus, &mut buf);
            buf[0]
        });
    }

    // scalar encode: one user, m shares
    {
        let enc = CloakEncoder::new(modulus, 1_000_000, m);
        let mut rng = ChaCha20Rng::seed_from_u64(3);
        let mut out = vec![0u64; m];
        b.run_items(&format!("encode scalar (m={m})"), m as f64, || {
            enc.encode_into(0.37, &mut rng, &mut out);
            out[m - 1]
        });
    }

    // vector encode: 256 coordinates × m shares (the FL layout)
    {
        let enc = CloakEncoder::new(modulus, 1_000_000, m);
        let mut rng = ChaCha20Rng::seed_from_u64(4);
        let d = 256usize;
        let xbars: Vec<u64> = (0..d as u64).map(|j| j * 977).collect();
        let mut out = vec![0u64; d * m];
        b.run_items(&format!("encode vector (d=256, m={m})"), (d * m) as f64, || {
            enc.encode_vector_into(&xbars, &mut rng, &mut out);
            out[0]
        });
    }

    // analyzer mod-sum over a big pool
    {
        let ring = ModRing::new(modulus);
        let mut rng = ChaCha20Rng::seed_from_u64(5);
        let pool: Vec<u64> = (0..262_144).map(|_| rng.gen_range(modulus)).collect();
        b.run_items("ring sum (256k messages)", pool.len() as f64, || ring.sum(&pool));
    }

    // analyzer end-to-end (sum + decision)
    {
        let n = 4096;
        let k = 10 * n as u64;
        let modulus_small = {
            let v = 3 * n as u64 * k + 10_001;
            if v % 2 == 0 {
                v + 1
            } else {
                v
            }
        };
        let ana = Analyzer::new(modulus_small, k, n);
        let mut rng = ChaCha20Rng::seed_from_u64(6);
        let pool: Vec<u64> = (0..n * 16).map(|_| rng.gen_range(modulus_small)).collect();
        b.run_items("analyze (n=4096, m=16)", pool.len() as f64, || ana.analyze(&pool));
    }

    // Fisher–Yates shuffle throughput
    {
        let mut fy = FisherYates::new(ChaCha20Rng::seed_from_u64(7));
        let mut pool: Vec<u64> = (0..262_144).collect();
        b.run_items("fisher-yates (256k)", pool.len() as f64, || {
            fy.shuffle(&mut pool);
            pool[0]
        });
    }

    // engine round on the shard axis: the full encode→shuffle→analyze hot
    // path at S = 1 vs S = cores (d = 128 instances, n = 64 clients)
    {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
        let (n, d, enc_m) = (64usize, 128usize, 8usize);
        let plan = ProtocolPlan::exact_secure_agg(n, 1 << 10, enc_m);
        let seeds = DerivedClientSeeds::new(9);
        let mut rng = ChaCha20Rng::seed_from_u64(9);
        let inputs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.gen_f64()).collect()).collect();
        let mut sweep = vec![1usize, cores];
        sweep.sort_unstable();
        sweep.dedup();
        for s in sweep {
            let mut engine = Engine::new(EngineConfig::new(plan.clone(), d).with_shards(s), 9);
            b.run_sharded(
                &format!("engine round (n={n}, d={d}, m={enc_m}, S={s})"),
                (n * d * enc_m) as f64,
                s,
                || {
                    engine
                        .run_round(&RoundInput::Vectors(&inputs), &seeds)
                        .expect("engine round")
                        .estimates[0]
                },
            );
        }
    }

    // streaming round on the same shard axis: nested Vec<Vec<u64>> pools
    // vs the flat-arena entry — identical bytes, different memory layout,
    // so the delta is pure allocation/locality (the tentpole's target)
    {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
        let (n, d, enc_m) = (64usize, 128usize, 8usize);
        let plan = ProtocolPlan::exact_secure_agg(n, 1 << 10, enc_m);
        let stream_m = plan.num_messages;
        let seeds = DerivedClientSeeds::new(11);
        let mut rng = ChaCha20Rng::seed_from_u64(11);
        let inputs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.gen_f64()).collect()).collect();
        let reference = Engine::new(EngineConfig::new(plan.clone(), d).with_shards(1), 11);
        let mut pools = vec![Vec::new(); d];
        for i in 0..n {
            let shares = reference
                .encode_client_shares(0, i as u32, &RoundInput::Vectors(&inputs), &seeds)
                .expect("encode");
            for (j, pool) in pools.iter_mut().enumerate() {
                pool.extend_from_slice(&shares[j * stream_m..(j + 1) * stream_m]);
            }
        }
        let flat: Vec<u64> = pools.concat();
        let mut sweep = vec![1usize, cores];
        sweep.sort_unstable();
        sweep.dedup();
        for s in sweep {
            let items = (n * d * stream_m) as f64;
            let mut nested =
                Engine::new(EngineConfig::new(plan.clone(), d).with_shards(s), 11);
            b.run_sharded(
                &format!("streaming nested pools (n={n}, d={d}, S={s})"),
                items,
                s,
                || nested.run_round_streaming(&pools, n).expect("nested round").estimates[0],
            );
            let mut arena =
                Engine::new(EngineConfig::new(plan.clone(), d).with_shards(s), 11);
            b.run_sharded(
                &format!("streaming flat arena (n={n}, d={d}, S={s})"),
                items,
                s,
                || {
                    arena.run_round_streaming_flat(&flat, n).expect("flat round").estimates
                        [0]
                },
            );
        }
    }

    b.report();
    b.write_json("BENCH_encoder_hotpath.json").expect("write BENCH_encoder_hotpath.json");

    // Perf gate for EXPERIMENTS.md §Perf: the vector encoder must beat
    // 10M shares/s/core (the practical target; see DESIGN.md §7).
    let vec_m = b
        .results()
        .iter()
        .find(|r| r.name.contains("encode vector"))
        .expect("vector case");
    let tput = vec_m.throughput().unwrap();
    println!("\nvector encoder throughput: {:.1}M shares/s", tput / 1e6);
    assert!(tput > 10.0e6, "vector encode below 10M shares/s: {tput}");
    println!("encoder_hotpath: OK");
}
