//! SCALE: "total work and communication of our new protocol scales
//! near-linearly with the number of users" (§1.2) vs Bonawitz's O(n²) —
//! plus the engine's shard-scaling axis.
//!
//!     cargo bench --bench scalability
//!
//! Part 1 measures wall-clock of a full aggregation round (encode →
//! shuffle → analyze) and total simulated bytes for both protocols across
//! n; fits the growth exponent. Bonawitz's quadratic key exchange blows up
//! by n ≈ 2000 while the cloak round stays near-linear in n·m.
//!
//! Part 2 sweeps the engine shard count S for a wide round (d = 256
//! instances) and writes BENCH_scalability.json (benchkit schema with the
//! `shards` field), so scaling runs are comparable across machines.

use cloak_agg::baselines::{bonawitz::BonawitzProtocol, AggregationProtocol, CloakProtocol};
use cloak_agg::engine::{DerivedClientSeeds, Engine, EngineConfig, RoundInput};
use cloak_agg::params::ProtocolPlan;
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};
use cloak_agg::util::benchkit::{format_ns, Bench};
use std::time::{Duration, Instant};

fn measure(p: &mut dyn AggregationProtocol, n: usize) -> (f64, u64) {
    let mut rng = SplitMix64::seed_from_u64(3);
    let xs: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
    let t0 = Instant::now();
    let (_, traffic) = p.aggregate(&xs);
    (t0.elapsed().as_secs_f64(), traffic.bytes)
}

fn fit_exponent(ns: &[usize], ys: &[f64]) -> f64 {
    // least-squares slope in log-log space
    let lx: Vec<f64> = ns.iter().map(|&n| (n as f64).ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|&y| y.max(1e-12).ln()).collect();
    let mx = lx.iter().sum::<f64>() / lx.len() as f64;
    let my = ly.iter().sum::<f64>() / ly.len() as f64;
    let num: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den
}

fn protocol_comparison() {
    let ns = [250usize, 500, 1_000, 2_000, 4_000];
    let mut table = Table::new(
        "scalability — one full round, wall-clock and bytes",
        &["n", "cloak secs", "cloak bytes", "bonawitz secs", "bonawitz bytes"],
    );
    let mut cloak_t = Vec::new();
    let mut bona_t = Vec::new();
    let mut cloak_b = Vec::new();
    let mut bona_b = Vec::new();
    for &n in &ns {
        let (ct, cb) = measure(&mut CloakProtocol::theorem1(n, 1.0, 1e-6, 1).expect("plan"), n);
        let (bt, bb) = measure(&mut BonawitzProtocol::new(n, 10 * n as u64, 2), n);
        cloak_t.push(ct);
        bona_t.push(bt);
        cloak_b.push(cb as f64);
        bona_b.push(bb as f64);
        table.row(&[
            n.to_string(),
            format!("{ct:.4}"),
            fmt_f(cb as f64),
            format!("{bt:.4}"),
            fmt_f(bb as f64),
        ]);
    }
    println!("{}", table.emit("scalability.txt"));

    let e_cloak_bytes = fit_exponent(&ns, &cloak_b);
    let e_bona_bytes = fit_exponent(&ns, &bona_b);
    let e_cloak_time = fit_exponent(&ns, &cloak_t);
    let e_bona_time = fit_exponent(&ns, &bona_t);
    println!(
        "\nfitted growth exponents (bytes): cloak n^{e_cloak_bytes:.2}, bonawitz n^{e_bona_bytes:.2}"
    );
    println!(
        "fitted growth exponents (time):  cloak n^{e_cloak_time:.2}, bonawitz n^{e_bona_time:.2}"
    );
    // communication: cloak near-linear (n·polylog), bonawitz quadratic
    assert!(e_cloak_bytes < 1.35, "cloak bytes exponent {e_cloak_bytes}");
    assert!(e_bona_bytes > 1.7, "bonawitz bytes exponent {e_bona_bytes}");
    // compute: bonawitz grows strictly faster than cloak
    assert!(
        e_bona_time > e_cloak_time + 0.3,
        "bonawitz time must grow faster: {e_bona_time} vs {e_cloak_time}"
    );
}

/// One engine round at shard count `shards`; returns the configured engine.
fn engine_for(n: usize, d: usize, m: usize, shards: usize) -> Engine {
    let plan = ProtocolPlan::exact_secure_agg(n, 1 << 10, m);
    Engine::new(EngineConfig::new(plan, d).with_shards(shards), 77)
}

fn shard_sweep() {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let (n, d, m) = (128usize, 256usize, 8usize);
    let msgs = (n * d * m) as f64;
    let mut sweep: Vec<usize> = vec![1, 2, 4, cores];
    sweep.sort_unstable();
    sweep.dedup();

    let mut b = Bench::new("scalability_shards").with_window(
        Duration::from_millis(50),
        Duration::from_millis(300),
        5,
    );
    let seeds = DerivedClientSeeds::new(5);
    let mut rng = SplitMix64::seed_from_u64(5);
    let inputs: Vec<Vec<f64>> =
        (0..n).map(|_| (0..d).map(|_| rng.gen_f64()).collect()).collect();

    let mut mean_by_shards: Vec<(usize, f64)> = Vec::new();
    for &s in &sweep {
        let mut engine = engine_for(n, d, m, s);
        let name = format!("round n={n} d={d} m={m} S={s}");
        let meas = b.run_sharded(&name, msgs, s, || {
            engine
                .run_round(&RoundInput::Vectors(&inputs), &seeds)
                .expect("engine round")
                .estimates[0]
        });
        mean_by_shards.push((s, meas.mean_ns));
    }
    b.report();
    b.write_json("BENCH_scalability.json").expect("write BENCH_scalability.json");
    println!("\nwrote BENCH_scalability.json ({} shard points)", mean_by_shards.len());

    let (_, t_single) = mean_by_shards[0];
    let &(s_max, t_multi) = mean_by_shards.last().unwrap();
    println!(
        "shard scaling at d={d}: S=1 {} vs S={s_max} {}",
        format_ns(t_single),
        format_ns(t_multi)
    );
    // Acceptance: per-round wall time at S=cores must not regress vs the
    // single-shard round (generous headroom for small/noisy machines).
    assert!(
        t_multi <= t_single * 1.6,
        "sharded round regressed: S={s_max} {t_multi}ns vs S=1 {t_single}ns"
    );
}

fn main() {
    protocol_comparison();
    shard_sweep();
    println!("scalability: shape OK");
}
