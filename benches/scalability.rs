//! SCALE: "total work and communication of our new protocol scales
//! near-linearly with the number of users" (§1.2) vs Bonawitz's O(n²).
//!
//!     cargo bench --bench scalability
//!
//! Measures wall-clock of a full aggregation round (encode → shuffle →
//! analyze) and total simulated bytes for both protocols across n; fits
//! the growth exponent. Bonawitz's quadratic key exchange blows up by
//! n ≈ 2000 while the cloak round stays near-linear in n·m.

use cloak_agg::baselines::{bonawitz::BonawitzProtocol, AggregationProtocol, CloakProtocol};
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};
use std::time::Instant;

fn measure(p: &mut dyn AggregationProtocol, n: usize) -> (f64, u64) {
    let mut rng = SplitMix64::seed_from_u64(3);
    let xs: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
    let t0 = Instant::now();
    let (_, traffic) = p.aggregate(&xs);
    (t0.elapsed().as_secs_f64(), traffic.bytes)
}

fn fit_exponent(ns: &[usize], ys: &[f64]) -> f64 {
    // least-squares slope in log-log space
    let lx: Vec<f64> = ns.iter().map(|&n| (n as f64).ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|&y| y.max(1e-12).ln()).collect();
    let mx = lx.iter().sum::<f64>() / lx.len() as f64;
    let my = ly.iter().sum::<f64>() / ly.len() as f64;
    let num: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den
}

fn main() {
    let ns = [250usize, 500, 1_000, 2_000, 4_000];
    let mut table = Table::new(
        "scalability — one full round, wall-clock and bytes",
        &["n", "cloak secs", "cloak bytes", "bonawitz secs", "bonawitz bytes"],
    );
    let mut cloak_t = Vec::new();
    let mut bona_t = Vec::new();
    let mut cloak_b = Vec::new();
    let mut bona_b = Vec::new();
    for &n in &ns {
        let (ct, cb) = measure(&mut CloakProtocol::theorem1(n, 1.0, 1e-6, 1), n);
        let (bt, bb) = measure(&mut BonawitzProtocol::new(n, 10 * n as u64, 2), n);
        cloak_t.push(ct);
        bona_t.push(bt);
        cloak_b.push(cb as f64);
        bona_b.push(bb as f64);
        table.row(&[
            n.to_string(),
            format!("{ct:.4}"),
            fmt_f(cb as f64),
            format!("{bt:.4}"),
            fmt_f(bb as f64),
        ]);
    }
    println!("{}", table.emit("scalability.txt"));

    let e_cloak_bytes = fit_exponent(&ns, &cloak_b);
    let e_bona_bytes = fit_exponent(&ns, &bona_b);
    let e_cloak_time = fit_exponent(&ns, &cloak_t);
    let e_bona_time = fit_exponent(&ns, &bona_t);
    println!(
        "\nfitted growth exponents (bytes): cloak n^{e_cloak_bytes:.2}, bonawitz n^{e_bona_bytes:.2}"
    );
    println!(
        "fitted growth exponents (time):  cloak n^{e_cloak_time:.2}, bonawitz n^{e_bona_time:.2}"
    );
    // communication: cloak near-linear (n·polylog), bonawitz quadratic
    assert!(e_cloak_bytes < 1.35, "cloak bytes exponent {e_cloak_bytes}");
    assert!(e_bona_bytes > 1.7, "bonawitz bytes exponent {e_bona_bytes}");
    // compute: bonawitz grows strictly faster than cloak
    assert!(
        e_bona_time > e_cloak_time + 0.3,
        "bonawitz time must grow faster: {e_bona_time} vs {e_cloak_time}"
    );
    println!("scalability: shape OK");
}
