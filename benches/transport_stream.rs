//! TRANSPORT: streaming ingestion throughput across loss rates × shard
//! counts — the wire-path counterpart of `benches/scalability.rs`.
//!
//!     cargo bench --bench transport_stream
//!
//! Each case times the server-side half of a streamed round — SimNet
//! (seeded loss/duplication/jitter) → decode + validate → bounded-queue
//! scatter → shuffle + renormalized analyze — replaying frames that were
//! cloak-encoded once outside the timer (encode is shard-independent and
//! would otherwise flatten the shard axis). Results land in
//! BENCH_transport_stream.json (benchkit schema, `shards` axis populated)
//! so loss-rate scaling runs are comparable across machines.

use cloak_agg::engine::{DerivedClientSeeds, Engine, EngineConfig, RoundInput};
use cloak_agg::params::ProtocolPlan;
use cloak_agg::rng::derive_seed;
use cloak_agg::transport::channel::{Channel, Loopback, SimNet, SimNetConfig};
use cloak_agg::transport::streaming::{send_cohort, StreamConfig, StreamingRound};
use cloak_agg::util::benchkit::Bench;
use std::time::Duration;

fn main() {
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let (n, d) = (128usize, 64usize);
    let plan = ProtocolPlan::exact_secure_agg(n, 100, 8);
    let m = plan.num_messages;
    let k = plan.scale;
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..d).map(|j| ((i * 3 + j * 11) % 100) as f64 / 100.0).collect())
        .collect();
    let seeds = DerivedClientSeeds::new(9);
    let no_drops = vec![false; n];

    // Client-side encode is shard-independent and identical across every
    // sweep point, so it runs ONCE, outside the timer: each timed
    // iteration replays the same pre-encoded frame bytes through a fresh
    // SimNet and a fresh engine (whose round id 0 matches the frames).
    // The timer then sees the server-side ingestion path alone — fault
    // injection, decode + validate, queue scatter, shuffle, renormalized
    // analyze — which is the half the shard axis actually scales.
    let frames: Vec<Vec<u8>> = {
        let reference = Engine::new(EngineConfig::new(plan.clone(), d).with_shards(1), 9);
        let mut ch = Loopback::new();
        send_cohort(&reference, &seeds, &RoundInput::Vectors(&inputs), &no_drops, &mut ch)
            .expect("encode cohort");
        std::iter::from_fn(|| ch.recv().map(|(_, bytes)| bytes)).collect()
    };

    let mut shard_sweep: Vec<usize> = vec![1, 2, 4, cores];
    shard_sweep.sort_unstable();
    shard_sweep.dedup();
    let loss_sweep = [0.0f64, 0.1, 0.3];

    let mut b = Bench::new("transport_stream").with_window(
        Duration::from_millis(50),
        Duration::from_millis(250),
        5,
    );
    for &loss in &loss_sweep {
        for &s in &shard_sweep {
            let mut stream = 0u64;
            let name = format!("round n={n} d={d} loss={loss} S={s}");
            let cfg = StreamConfig::new(n).with_quorum(n / 4).with_deadline(1.0);
            b.run_sharded(&name, (n * d * m) as f64, s, || {
                stream += 1;
                let mut engine =
                    Engine::new(EngineConfig::new(plan.clone(), d).with_shards(s), 9);
                let mut net = SimNet::new(
                    SimNetConfig::new(derive_seed(stream, (loss * 100.0) as u64))
                        .with_loss(loss)
                        .with_duplicate(0.02),
                );
                for f in &frames {
                    net.send(f.clone());
                }
                let out = StreamingRound::drive(&mut engine, &mut net, &cfg)
                    .expect("streaming round");
                // Sanity on every timed iteration: renormalized exactness
                // over whoever survived this particular scenario.
                let survivor_sum: u64 = out
                    .contributed
                    .iter()
                    .map(|&i| (inputs[i as usize][0] * k as f64).floor() as u64)
                    .sum();
                assert!(
                    (out.result.estimates[0] - survivor_sum as f64 / k as f64).abs() < 1e-9,
                    "streamed estimate drifted from surviving-cohort sum"
                );
                out.result.estimates[0]
            });
        }
    }
    b.report();
    b.write_json("BENCH_transport_stream.json").expect("write BENCH_transport_stream.json");
    println!(
        "\nwrote BENCH_transport_stream.json ({} cases: {} loss rates x {} shard counts)",
        loss_sweep.len() * shard_sweep.len(),
        loss_sweep.len(),
        shard_sweep.len()
    );
    println!("transport_stream: shape OK");
}
