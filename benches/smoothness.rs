//! LEM1 + LEM7-9: empirical γ-smoothness of encoder-pair outputs
//! (Definition 2, Lemma 1, Corollary 1) and truncated-discrete-Laplace
//! moment checks (Definition 3, Lemmas 7–9).
//!
//!     cargo bench --bench smoothness
//!
//! Part 1 enumerates all C(2m, m) subset sums of two encoders' unioned
//! output for m ∈ {6..12} and reports the measured γ next to Lemma 1's
//! failure bound: γ falls rapidly with m (at fixed N) exactly as the
//! lemma predicts. Part 2 sweeps D_{N,p} and compares empirical moments
//! to the closed forms.

use cloak_agg::encoder::CloakEncoder;
use cloak_agg::privacy::dlaplace::TruncatedDiscreteLaplace;
use cloak_agg::privacy::smoothness::{lemma1_failure_bound, measure};
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{ChaCha20Rng, SeedableRng};
use cloak_agg::util::Welford;

fn main() {
    // ---- part 1: γ-smoothness vs m --------------------------------------
    let n_mod = 31u64; // small N so 2^{2m} >> N² (Lemma 1 regime)
    let mut table = Table::new(
        "Lemma 1 — empirical gamma of E(x1)∪E(x2) over Z_31",
        &["m", "C(2m,m)", "measured gamma", "distinct", "Lemma1 bound (gamma=0.5)"],
    );
    let mut gammas = Vec::new();
    for &m in &[6usize, 8, 10, 12] {
        let enc = CloakEncoder::new(n_mod, 10, m);
        let mut rng = ChaCha20Rng::seed_from_u64(100 + m as u64);
        // average gamma over a few draws
        let mut g_acc = 0.0;
        let mut subsets = 0u64;
        let mut distinct_any = false;
        let draws = 5;
        for _ in 0..draws {
            let mut e = enc.encode_scalar(0.4, &mut rng);
            e.extend(enc.encode_scalar(0.9, &mut rng));
            let rep = measure(&e, n_mod);
            g_acc += rep.gamma;
            subsets = rep.subsets;
            distinct_any |= rep.distinct;
        }
        let gamma = g_acc / draws as f64;
        gammas.push(gamma);
        table.row(&[
            m.to_string(),
            subsets.to_string(),
            fmt_f(gamma),
            distinct_any.to_string(),
            fmt_f(lemma1_failure_bound(m, n_mod, 0.5)),
        ]);
    }
    println!("{}", table.emit("smoothness.txt"));
    // γ decreases with m (sampling-noise floor ~ sqrt(N/C(2m,m)))
    assert!(
        gammas.last().unwrap() < &gammas[0],
        "gamma must shrink with m: {gammas:?}"
    );
    assert!(gammas.last().unwrap() < &0.05, "m=12 gamma {:.4}", gammas.last().unwrap());

    // ---- part 2: D_{N,p} moments ----------------------------------------
    let mut t2 = Table::new(
        "Lemmas 7-9 — truncated discrete Laplace moments",
        &["N", "p", "mean (≈0)", "empirical var", "Lemma 8 bound", "log-Lipschitz ok"],
    );
    for &(n, p) in &[(101u64, 0.5f64), (1001, 0.9), (10_001, 0.99)] {
        let d = TruncatedDiscreteLaplace::new(n, p);
        let mut rng = ChaCha20Rng::seed_from_u64(7);
        let mut w = Welford::default();
        for _ in 0..100_000 {
            w.push(d.sample(&mut rng) as f64);
        }
        // Lemma 7 spot check: pmf ratios within [p^|t|, p^-|t|]
        let mut lipschitz_ok = true;
        for k in [-5i64, 0, 5] {
            for t in [-3i64, -1, 1, 3] {
                let a = d.pmf(k + t);
                let b = d.pmf(k);
                if b > 0.0 && a > 0.0 {
                    let ratio = a / b;
                    let lo = p.powi(t.unsigned_abs() as i32);
                    let hi = p.powi(-(t.unsigned_abs() as i32));
                    lipschitz_ok &= ratio >= lo * 0.999 && ratio <= hi * 1.001;
                }
            }
        }
        assert!(w.variance() <= d.variance() * 1.05);
        t2.row(&[
            n.to_string(),
            p.to_string(),
            fmt_f(w.mean()),
            fmt_f(w.variance()),
            fmt_f(d.variance()),
            lipschitz_ok.to_string(),
        ]);
        assert!(lipschitz_ok);
    }
    println!("{}", t2.emit("smoothness.txt"));
    println!("smoothness: shape OK");
}
