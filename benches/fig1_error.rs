//! FIG1-err + THM1: regenerate Figure 1's expected-error column and
//! Theorem 1's O((1/ε)√log(1/δ)) error law.
//!
//!     cargo bench --bench fig1_error
//!
//! Series 1 — error vs n at (ε, δ) = (1, 10⁻⁶): cloak stays flat
//! (polylog), balle grows ~n^{1/6}, local DP grows ~√n, central DP is the
//! 1/ε floor. Series 2 — cloak error vs ε at fixed n: ∝ 1/ε. Series 3 —
//! cloak error vs δ at fixed n, ε: ∝ √log(1/δ).

use cloak_agg::baselines::{
    balle::BalleProtocol, central_dp::CentralDpProtocol, cheu::CheuProtocol,
    local_dp::LocalDpProtocol, AggregationProtocol, CloakProtocol,
};
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};

fn mean_abs_error(p: &mut dyn AggregationProtocol, n: usize, trials: usize, seed: u64) -> f64 {
    let mut rng = SplitMix64::seed_from_u64(seed);
    let xs: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
    let truth: f64 = xs.iter().sum();
    (0..trials).map(|_| (p.aggregate(&xs).0 - truth).abs()).sum::<f64>() / trials as f64
}

fn main() {
    let (eps, delta) = (1.0, 1e-6);
    let trials = 6;

    // ---- series 1: error vs n -----------------------------------------
    let ns = [4_000usize, 16_000, 64_000, 256_000];
    let mut table = Table::new(
        "Fig. 1 — expected |error| vs n (measured, eps=1, delta=1e-6)",
        &["n", "cloak thm1", "cloak thm2", "cheu [7]", "balle [4]", "local DP", "central DP"],
    );
    let mut cloak_errs = Vec::new();
    let mut local_errs = Vec::new();
    let mut balle_preds = Vec::new();
    for &n in &ns {
        let mut c1 = CloakProtocol::theorem1(n, eps, delta, 1).expect("plan");
        let e_cloak1 = mean_abs_error(&mut c1, n, trials, 7);
        let mut c2 = CloakProtocol::theorem2(n, eps, delta, 2).expect("plan");
        let e_cloak2 = mean_abs_error(&mut c2, n, trials, 7);
        let e_cheu = mean_abs_error(&mut CheuProtocol::new(n, eps, delta, 3), n, trials, 7);
        let balle = BalleProtocol::new(n, eps, delta, 4);
        balle_preds.push((balle.gamma() * n as f64 / 12.0).sqrt() / (1.0 - balle.gamma()));
        let e_balle =
            mean_abs_error(&mut BalleProtocol::new(n, eps, delta, 4), n, trials, 7);
        let e_local =
            mean_abs_error(&mut LocalDpProtocol::new(n, eps, 100, 5), n, trials, 7);
        let e_central = mean_abs_error(&mut CentralDpProtocol::new(n, eps, 6), n, 20, 7);
        cloak_errs.push(e_cloak1);
        local_errs.push(e_local);
        table.row(&[
            n.to_string(),
            fmt_f(e_cloak1),
            fmt_f(e_cloak2),
            fmt_f(e_cheu),
            fmt_f(e_balle),
            fmt_f(e_local),
            fmt_f(e_central),
        ]);
    }
    println!("{}", table.emit("fig1_error.txt"));

    // Shape: cloak flat in n (64x more users => < 2x error), local ~√n (≥4x).
    let cloak_growth = cloak_errs.last().unwrap() / cloak_errs[0];
    let local_growth = local_errs.last().unwrap() / local_errs[0];
    println!("error growth 4k→256k: cloak ×{cloak_growth:.2} (flat), local DP ×{local_growth:.1} (~√n ⇒ ×8)");
    assert!(cloak_growth < 2.0, "cloak error must be flat in n: {cloak_growth}");
    assert!(local_growth > 3.0, "local DP error must grow ~sqrt(n): {local_growth}");
    // Balle's n^{1/6} growth only dominates once γ ≪ 1 (n ≳ 10^5 here);
    // below that the 1/(1−γ) saturation factor *shrinks* with n, which the
    // measured column above shows. Assert the asymptotic law analytically:
    let pred = |n: usize| {
        let p = BalleProtocol::new(n, eps, delta, 0);
        (p.gamma() * n as f64 / 12.0).sqrt() / (1.0 - p.gamma())
    };
    let (p18, p24) = (pred(1 << 18), pred(1 << 24));
    let growth = p24 / p18;
    println!("balle analytic error growth 2^18→2^24: ×{growth:.2} (n^1/6 ⇒ ×2)");
    assert!(growth > 1.5 && growth < 2.6, "balle asymptotic growth {growth}");
    let _ = balle_preds;

    // ---- series 2: cloak error vs ε -------------------------------------
    let n = 16_000;
    let mut t2 = Table::new("Thm 1 — error vs eps (n=16000)", &["eps", "measured", "bound"]);
    let mut errs_eps = Vec::new();
    for &e in &[0.25f64, 0.5, 1.0, 2.0, 4.0] {
        let mut p = CloakProtocol::theorem1(n, e, delta, 8).expect("plan");
        let err = mean_abs_error(&mut p, n, trials, 9);
        let plan = cloak_agg::params::ProtocolPlan::theorem1(n, e, delta).unwrap();
        errs_eps.push(err);
        t2.row(&[e.to_string(), fmt_f(err), fmt_f(plan.error_bound())]);
    }
    println!("{}", t2.emit("fig1_error.txt"));
    // 1/ε law: ε×16 ⇒ error ÷(~16); generous factor-4 slack
    assert!(errs_eps[0] / errs_eps[4] > 4.0, "error must scale ~1/eps");

    // ---- series 3: cloak error vs δ -------------------------------------
    let mut t3 = Table::new("Thm 1 — error vs delta (n=16000, eps=1)", &["delta", "measured", "bound"]);
    for &d in &[1e-4f64, 1e-6, 1e-8, 1e-10] {
        let mut p = CloakProtocol::theorem1(n, 1.0, d, 10).expect("plan");
        let err = mean_abs_error(&mut p, n, trials, 11);
        let plan = cloak_agg::params::ProtocolPlan::theorem1(n, 1.0, d).unwrap();
        t3.row(&[format!("{d:.0e}"), fmt_f(err), fmt_f(plan.error_bound())]);
    }
    println!("{}", t3.emit("fig1_error.txt"));
    println!("fig1_error: shape OK");
}
