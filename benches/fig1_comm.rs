//! FIG1-comm: regenerate Figure 1's communication columns empirically.
//!
//!     cargo bench --bench fig1_comm
//!
//! Sweeps n ∈ {10^2 … 10^6} at (ε, δ) = (1, 10⁻⁶) and prints, per
//! protocol, messages/user and message bits — the paper's claim: the
//! cloak protocol is the only one with BOTH columns polylog(n)
//! (Balle is O(1) messages but pays n^{1/6} error — see fig1_error).

use cloak_agg::baselines::{
    balle::BalleProtocol, bonawitz::BonawitzProtocol, cheu::CheuProtocol, AggregationProtocol,
    CloakProtocol,
};
use cloak_agg::report::{fmt_f, Table};

fn main() {
    let (eps, delta) = (1.0, 1e-6);
    let ns = [100usize, 1_000, 10_000, 100_000, 1_000_000];

    let mut table = Table::new(
        "Fig. 1 — communication columns (measured plans), eps=1, delta=1e-6",
        &["n", "protocol", "msgs/user", "bits/msg", "bits/user"],
    );
    let mut cloak_series = Vec::new();
    let mut cheu_series = Vec::new();
    for &n in &ns {
        let rows: Vec<(String, f64, u32)> = vec![
            {
                let p = CheuProtocol::new(n, eps, delta, 1);
                ("cheu [7]".into(), p.messages_per_user(), p.message_bits())
            },
            {
                let p = BalleProtocol::new(n, eps, delta, 2);
                ("balle [4]".into(), p.messages_per_user(), p.message_bits())
            },
            {
                let p = CloakProtocol::theorem1(n, eps, delta, 3).expect("plan");
                ("cloak thm1".into(), p.messages_per_user(), p.message_bits())
            },
            {
                let p = BonawitzProtocol::new(n, 10 * n as u64, 4);
                ("bonawitz [6]".into(), p.messages_per_user(), p.message_bits())
            },
        ];
        for (name, msgs, bits) in rows {
            if name.starts_with("cloak") {
                cloak_series.push(msgs);
            }
            if name.starts_with("cheu") {
                cheu_series.push(msgs);
            }
            table.row(&[
                n.to_string(),
                name,
                fmt_f(msgs),
                bits.to_string(),
                fmt_f(msgs * bits as f64),
            ]);
        }
    }
    println!("{}", table.emit("fig1_comm.txt"));

    // Shape assertions (who grows how): 10^2 -> 10^6 is 4 decades.
    let cloak_growth = cloak_series.last().unwrap() / cloak_series.first().unwrap();
    let cheu_growth = cheu_series.last().unwrap() / cheu_series.first().unwrap();
    println!("growth 10^2→10^6: cloak ×{cloak_growth:.2} (polylog), cheu ×{cheu_growth:.0} (√n ⇒ ×100)");
    assert!(cloak_growth < 3.0, "cloak must grow polylog: {cloak_growth}");
    assert!(cheu_growth > 50.0, "cheu must grow ~√n: {cheu_growth}");
    println!("fig1_comm: shape OK");
}
