//! ELASTIC: full-round throughput across rebalance policy × churn — what
//! the control plane costs, and what it saves once a shard dies.
//!
//!     cargo bench --bench elastic_round
//!
//! Policies: `static` (dead shard keeps its range — every round pays the
//! retry budget plus a takeover), `even-split` and `proportional` (the
//! dead shard is parked after its first loss, so churn rounds cost the
//! same as healthy ones). Churn: `none` (all links healthy — the control
//! plane's overhead over the plain barrier) and `dead-shard` (one link
//! silent past the retry budget from its first work frame). Every case is
//! gate-checked bit-identical to the in-process `Engine` before the timer
//! starts — takeover and re-ranging move wall-clock, never bits. Results
//! land in BENCH_elastic_round.json (benchkit schema, `shards` axis
//! populated).

use std::time::Duration;

use cloak_agg::cluster::{ClusterEngine, ClusterTuning, RemoteShardBackend};
use cloak_agg::control::{
    ElasticController, ElasticTuning, EvenSplit, Proportional, RebalancePolicy, StaticRanges,
};
use cloak_agg::engine::{DerivedClientSeeds, Engine, EngineConfig, RoundInput};
use cloak_agg::params::ProtocolPlan;
use cloak_agg::transport::channel::{Channel, Loopback, SimNet, SimNetConfig};
use cloak_agg::util::benchkit::Bench;

fn main() {
    let (n, d, s, seed) = (96usize, 32usize, 4usize, 9u64);
    let victim = s / 2;
    let plan = ProtocolPlan::exact_secure_agg(n, 100, 8);
    let m = plan.num_messages;
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..d).map(|j| ((i * 3 + j * 11) % 100) as f64 / 100.0).collect())
        .collect();
    let seeds = DerivedClientSeeds::new(seed);
    let cfg = EngineConfig::new(plan, d).with_shards(s);

    // The reference every case must reproduce bit-exactly.
    let mut reference = Engine::new(cfg.clone(), seed);
    let want =
        reference.run_round(&RoundInput::Vectors(&inputs), &seeds).expect("reference").estimates;

    let mut b = Bench::new("elastic_round").with_window(
        Duration::from_millis(50),
        Duration::from_millis(250),
        5,
    );

    let make_policy = |name: &str| -> Box<dyn RebalancePolicy> {
        match name {
            "static" => Box::new(StaticRanges),
            "even-split" => Box::new(EvenSplit),
            _ => Box::new(Proportional::default()),
        }
    };

    for policy_name in ["static", "even-split", "proportional"] {
        for churn in ["none", "dead-shard"] {
            let backend = RemoteShardBackend::over_channels(&cfg, |link| {
                let down: Box<dyn Channel> = if churn == "dead-shard" && link == victim {
                    // Handshake delivered, every work frame swallowed: the
                    // link is dead past the retry budget from round 0 on.
                    Box::new(SimNet::new(SimNetConfig::new(seed).with_silent_after(1)))
                } else {
                    Box::new(Loopback::new())
                };
                (down, Box::new(Loopback::new()) as _)
            })
            .with_tuning(ClusterTuning { max_retries: 1, ..ClusterTuning::default() });
            let controller = ElasticController::new(backend, make_policy(policy_name))
                .with_tuning(ElasticTuning { revive_every: 0, ..ElasticTuning::default() });
            let mut cluster = ClusterEngine::new(cfg.clone(), seed, Box::new(controller));

            // Gate: the elastic round must reproduce the engine bit-exactly
            // before this case's numbers mean anything — including through
            // the takeover the dead-shard churn forces.
            let gate = cluster
                .run_round(&RoundInput::Vectors(&inputs), &seeds)
                .expect("gate round");
            assert_eq!(gate.estimates, want, "policy={policy_name} churn={churn} diverged");
            if churn == "dead-shard" {
                assert!(cluster.shard_takeovers() >= 1, "churn case must take over");
            }

            let name = format!("round n={n} d={d} S={s} policy={policy_name} churn={churn}");
            b.run_sharded(&name, (n * d * m) as f64, s, || {
                cluster
                    .run_round(&RoundInput::Vectors(&inputs), &seeds)
                    .expect("elastic round")
                    .estimates[0]
            });
        }
    }

    b.report();
    b.write_json("BENCH_elastic_round.json").expect("write BENCH_elastic_round.json");
    println!("\nwrote BENCH_elastic_round.json");
}
