//! §3 open problem, probed empirically: "we cannot rule out that
//! m = O(log_n k) suffices to achieve error 1/k under sum-preserving
//! changes, using our protocol unchanged."
//!
//!     cargo bench --bench open_problem_small_m
//!
//! For fixed small N we enumerate the subset-sum distribution of
//! E(x₁)∪E(x₂) (the Lemma 3 quantity) at decreasing m and measure the
//! empirical γ — the effective per-swap privacy factor β = (1+γ)/(1−γ).
//! Two findings the conclusion anticipates: (a) γ degrades gracefully,
//! not catastrophically, as m shrinks toward log N; (b) correctness
//! (exact sums) holds for ALL m ≥ 1 — only privacy is at stake, so any
//! future improvement to Lemma 1 immediately transfers to the protocol
//! unchanged. Plus an hops-ablation: extra mixnet hops do NOT change the
//! observable distribution (one honest hop suffices), justifying the
//! 1-hop default (§Perf iteration 5).

use cloak_agg::encoder::CloakEncoder;
use cloak_agg::privacy::smoothness::measure;
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{ChaCha20Rng, SeedableRng};
use cloak_agg::shuffler::mixnet::{permutation_chi2, Mixnet};

fn main() {
    // ---- part 1: gamma vs m at fixed N ----------------------------------
    let n_mod = 31u64;
    let log_n = (n_mod as f64).log2(); // ≈ 4.95
    let mut table = Table::new(
        "open problem §3 — empirical gamma as m shrinks (N=31, log2 N ≈ 4.95)",
        &["m", "m/log2(N)", "mean gamma", "beta=(1+g)/(1-g)", "exact sums"],
    );
    let mut gammas = Vec::new();
    for &m in &[4usize, 5, 6, 8, 10, 12] {
        let enc = CloakEncoder::new(n_mod, 10, m);
        let mut rng = ChaCha20Rng::seed_from_u64(1000 + m as u64);
        let draws = 6;
        let mut g_acc = 0.0;
        let mut all_exact = true;
        for _ in 0..draws {
            let x1 = 0.4;
            let x2 = 0.9;
            let e1 = enc.encode_scalar(x1, &mut rng);
            let e2 = enc.encode_scalar(x2, &mut rng);
            // correctness at every m: shares still sum to the inputs
            all_exact &= enc.ring().sum(&e1) == enc.codec().encode(x1) % n_mod;
            all_exact &= enc.ring().sum(&e2) == enc.codec().encode(x2) % n_mod;
            let mut e = e1;
            e.extend(e2);
            g_acc += measure(&e, n_mod).gamma.min(10.0);
        }
        let gamma = g_acc / draws as f64;
        gammas.push(gamma);
        let beta = (1.0 + gamma) / (1.0 - gamma).max(1e-9);
        table.row(&[
            m.to_string(),
            format!("{:.2}", m as f64 / log_n),
            fmt_f(gamma),
            if gamma < 1.0 { fmt_f(beta) } else { "∞ (γ≥1)".into() },
            all_exact.to_string(),
        ]);
    }
    println!("{}", table.emit("open_problem_small_m.txt"));
    // monotone degradation, no cliff between m=2·log N and m=log N:
    assert!(gammas.windows(2).all(|w| w[0] >= w[1] * 0.8), "graceful: {gammas:?}");
    // by m ≈ 2.4·log2(N) the union is already usefully smooth
    assert!(gammas.last().unwrap() < &0.05);
    println!(
        "finding: gamma decays smoothly through m ≈ log2(N)…2.4·log2(N); correctness\n\
         is m-independent — consistent with the conjecture that smaller m may suffice."
    );

    // ---- part 2: mixnet hops ablation ------------------------------------
    let mut t2 = Table::new(
        "ablation — mixnet hops (uniformity chi², 24 dof, 48k trials)",
        &["hops", "chi2", "uniform (<64)?"],
    );
    for hops in [1usize, 3, 8] {
        let mut net = Mixnet::honest(42, hops);
        let (chi2, _dof) = permutation_chi2(&mut net, 48_000);
        t2.row(&[hops.to_string(), format!("{chi2:.1}"), (chi2 < 64.0).to_string()]);
        assert!(chi2 < 64.0, "hops={hops} chi2={chi2}");
    }
    println!("{}", t2.emit("open_problem_small_m.txt"));
    println!("ablation: extra hops change nothing observable — 1 honest hop = uniform.");
    println!("open_problem_small_m: OK");
}
