//! DURABLE: what write-ahead durability costs on the round hot path —
//! the bare stack vs the same stack behind a `DurableCoordinator`
//! journaling manifest + work units + commit per round, across
//! shards × cohort.
//!
//!     cargo bench --bench durable_round
//!
//! Every journal-on case is gate-checked bit-identical to its journal-off
//! twin before the timer starts (the journal must never perturb the
//! round). Results land in BENCH_durable_round.json (benchkit schema,
//! `shards` axis populated) and the file is re-validated through the
//! crate's own JSON parser before the process exits.

use std::time::Duration;

use cloak_agg::aggregator::AggregatorBuilder;
use cloak_agg::coordinator::durable::DurableCoordinator;
use cloak_agg::engine::{DerivedClientSeeds, EngineConfig, RoundInput};
use cloak_agg::params::ProtocolPlan;
use cloak_agg::storage::Store;
use cloak_agg::util::benchkit::Bench;
use cloak_agg::util::json::Json;

fn main() {
    let (d, seed) = (32usize, 13u64);
    let mut b = Bench::new("durable_round").with_window(
        Duration::from_millis(50),
        Duration::from_millis(250),
        5,
    );

    let mut expected_cases = 0usize;
    for s in [1usize, 2, 4] {
        for n in [32usize, 96] {
            let plan = ProtocolPlan::exact_secure_agg(n, 100, 8);
            let m = plan.num_messages;
            let cfg = EngineConfig::new(plan, d).with_shards(s);
            let inputs: Vec<Vec<f64>> = (0..n)
                .map(|i| (0..d).map(|j| ((i * 3 + j * 11) % 100) as f64 / 100.0).collect())
                .collect();
            let seeds = DerivedClientSeeds::new(seed);
            let items = (n * d * m) as f64;

            // Journal-off: the bare stack, and the gate reference.
            let mut bare = AggregatorBuilder::new(cfg.clone(), seed).build().expect("stack");
            let want = bare
                .run_round(&RoundInput::Vectors(&inputs), &seeds)
                .expect("reference round")
                .estimates;
            b.run_sharded(&format!("round n={n} d={d} S={s} journal=off"), items, s, || {
                bare.run_round(&RoundInput::Vectors(&inputs), &seeds)
                    .expect("bare round")
                    .estimates[0]
            });

            // Journal-on: same stack shape behind the write-ahead journal
            // (fresh store per case; the journal grows across the timed
            // rounds, as a real campaign's would).
            let mut root = std::env::temp_dir();
            root.push(format!("cloak_bench_durable_{}_{s}_{n}", std::process::id()));
            let store = Store::new(&root).expect("store");
            let agg = AggregatorBuilder::new(cfg, seed).build().expect("stack");
            let mut dur = DurableCoordinator::create(agg, seed, &store).expect("durable");
            let gate = dur.run_round(&inputs, &seeds).expect("gate round");
            assert_eq!(gate.estimates, want, "S={s} n={n}: journal perturbed the round");
            b.run_sharded(&format!("round n={n} d={d} S={s} journal=on"), items, s, || {
                dur.run_round(&inputs, &seeds).expect("durable round").estimates[0]
            });
            println!(
                "S={s} n={n}: journal holds {} KiB after the timed rounds",
                dur.journal_len_bytes() / 1024
            );
            drop(dur);
            let _ = std::fs::remove_dir_all(&root);
            expected_cases += 2;
        }
    }

    b.report();
    b.write_json("BENCH_durable_round.json").expect("write BENCH_durable_round.json");

    // --- validate the emitted benchkit JSON with the crate's parser -----
    let text = std::fs::read_to_string("BENCH_durable_round.json").expect("read back");
    let json = Json::parse(&text).expect("parse back");
    assert_eq!(
        json.get("group").and_then(|g| g.as_str()),
        Some("durable_round"),
        "bad benchkit group"
    );
    let cases = match json.get("cases") {
        Some(Json::Arr(cases)) => cases,
        _ => panic!("benchkit JSON has no cases array"),
    };
    assert_eq!(cases.len(), expected_cases, "case count drifted");
    for c in cases {
        assert!(
            c.get("mean_ns").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
            "case without positive mean_ns"
        );
        assert!(c.get("shards").and_then(|v| v.as_u64()).is_some(), "case without shards axis");
    }
    println!("benchkit JSON OK: BENCH_durable_round.json ({} cases)", cases.len());
    println!("\nwrote BENCH_durable_round.json");
}
