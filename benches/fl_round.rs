//! PERF/FL: full coordinator round throughput — the end-to-end number
//! the FL driver pays per round (encode ∥ ingest → shuffle → analyze).
//!
//!     cargo bench --bench fl_round
//!
//! Sweeps (clients, instances) and reports wall-clock, messages/s and the
//! per-stage budget. The coordinator must stay near-linear in n·d·m and
//! the shuffle+analyze side must not dominate encode (backpressure sized
//! correctly).

use cloak_agg::coordinator::{Coordinator, CoordinatorConfig};
use cloak_agg::params::{NeighborNotion, ProtocolPlan};
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};
use std::time::Instant;

fn round_secs(clients: usize, instances: usize, m: usize) -> (f64, u64) {
    let scale = 1u64 << 16;
    let modulus = {
        let v = 3 * clients as u64 * scale + 10_001;
        if v % 2 == 0 {
            v + 1
        } else {
            v
        }
    };
    let plan = ProtocolPlan::custom(
        clients,
        1.0,
        1e-6,
        NeighborNotion::SumPreserving,
        modulus,
        scale,
        m,
    );
    let mut coord = Coordinator::new(CoordinatorConfig::new(plan, instances), 77);
    let mut rng = SplitMix64::seed_from_u64(5);
    let inputs: Vec<Vec<f64>> = (0..clients)
        .map(|_| (0..instances).map(|_| rng.gen_f64()).collect())
        .collect();
    let t0 = Instant::now();
    let result = coord.run_round(&inputs).expect("round");
    (t0.elapsed().as_secs_f64(), result.traffic.messages)
}

fn main() {
    let m = 16usize;
    let mut table = Table::new(
        "coordinator round throughput (m=16, Thm 2 regime)",
        &["clients", "instances", "messages", "secs", "msgs/sec"],
    );
    let mut rates = Vec::new();
    for &(c, d) in &[(16usize, 256usize), (32, 256), (64, 256), (32, 1024), (32, 2688)] {
        let (secs, msgs) = round_secs(c, d, m);
        let rate = msgs as f64 / secs;
        rates.push(rate);
        table.row(&[
            c.to_string(),
            d.to_string(),
            msgs.to_string(),
            format!("{secs:.4}"),
            fmt_f(rate),
        ]);
    }
    println!("{}", table.emit("fl_round.txt"));

    // near-linear scaling: the msgs/s rate must stay within 4x across the
    // sweep (it grows with batch size as fixed costs amortize).
    let min_rate = rates.iter().cloned().fold(f64::MAX, f64::min);
    let max_rate = rates.iter().cloned().fold(0.0f64, f64::max);
    println!("\nround rate range: {} – {} msgs/s", fmt_f(min_rate), fmt_f(max_rate));
    assert!(max_rate / min_rate < 6.0, "rate spread {}", max_rate / min_rate);
    // absolute floor: ≥ 1M messages/s end-to-end on the largest round
    assert!(*rates.last().unwrap() > 1.0e6, "end-to-end rate {}", rates.last().unwrap());
    println!("fl_round: OK");
}
