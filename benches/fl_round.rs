//! PERF/FL: full coordinator round throughput — the end-to-end number
//! the FL driver pays per round (shard-parallel encode → shuffle →
//! analyze through the engine).
//!
//!     cargo bench --bench fl_round
//!
//! Sweeps (clients, instances) at the default shard configuration and
//! reports wall-clock and messages/s; then holds a fixed round and sweeps
//! backend × shard count through the `Aggregator` trait — the SAME
//! timing loop drives the in-process engine, the no-wire cluster and the
//! loopback cluster (stacks built by `AggregatorBuilder`, no per-backend
//! code). The coordinator must stay near-linear in n·d·m, and sharding
//! must not regress the single-shard round.

use cloak_agg::aggregator::{Aggregator, AggregatorBuilder};
use cloak_agg::coordinator::{Coordinator, CoordinatorConfig};
use cloak_agg::engine::{DerivedClientSeeds, EngineConfig, RoundInput};
use cloak_agg::params::ProtocolPlan;
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};
use std::time::Instant;

fn round_secs(clients: usize, instances: usize, m: usize, shards: usize) -> (f64, u64) {
    let plan = ProtocolPlan::exact_secure_agg(clients, 1 << 16, m);
    let mut cfg = CoordinatorConfig::new(plan, instances);
    cfg.shards = shards;
    let mut coord = Coordinator::new(cfg, 77);
    let mut rng = SplitMix64::seed_from_u64(5);
    let inputs: Vec<Vec<f64>> = (0..clients)
        .map(|_| (0..instances).map(|_| rng.gen_f64()).collect())
        .collect();
    let t0 = Instant::now();
    let result = coord.run_round(&inputs).expect("round");
    (t0.elapsed().as_secs_f64(), result.traffic.messages)
}

fn main() {
    let m = 16usize;
    let mut table = Table::new(
        "coordinator round throughput (m=16, Thm 2 regime, auto shards)",
        &["clients", "instances", "messages", "secs", "msgs/sec"],
    );
    let mut rates = Vec::new();
    for &(c, d) in &[(16usize, 256usize), (32, 256), (64, 256), (32, 1024), (32, 2688)] {
        let (secs, msgs) = round_secs(c, d, m, 0);
        let rate = msgs as f64 / secs;
        rates.push(rate);
        table.row(&[
            c.to_string(),
            d.to_string(),
            msgs.to_string(),
            format!("{secs:.4}"),
            fmt_f(rate),
        ]);
    }
    println!("{}", table.emit("fl_round.txt"));

    // near-linear scaling: the msgs/s rate must stay within 4x across the
    // sweep (it grows with batch size as fixed costs amortize).
    let min_rate = rates.iter().cloned().fold(f64::MAX, f64::min);
    let max_rate = rates.iter().cloned().fold(0.0f64, f64::max);
    println!("\nround rate range: {} – {} msgs/s", fmt_f(min_rate), fmt_f(max_rate));
    assert!(max_rate / min_rate < 6.0, "rate spread {}", max_rate / min_rate);
    // absolute floor: ≥ 1M messages/s end-to-end on the largest round
    assert!(*rates.last().unwrap() > 1.0e6, "end-to-end rate {}", rates.last().unwrap());

    // --- backend × shard axis through the Aggregator trait ---------------
    // One timing loop for every stack; only the builder's topology line
    // differs. `local` is the in-process engine (the floor), `inprocess`
    // is the cluster barrier on local threads (barrier overhead in
    // isolation), `loopback` adds the full wire codec.
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let mut sweep = vec![1usize, 2, 4, cores];
    sweep.sort_unstable();
    sweep.dedup();
    let (bn, bd) = (32usize, 1024usize);
    let plan = ProtocolPlan::exact_secure_agg(bn, 1 << 16, m);
    let mut rng = SplitMix64::seed_from_u64(5);
    let inputs: Vec<Vec<f64>> =
        (0..bn).map(|_| (0..bd).map(|_| rng.gen_f64()).collect()).collect();
    let seeds = DerivedClientSeeds::new(77);
    let mut backend_table = Table::new(
        "aggregator round vs backend x shard count (clients=32, d=1024, m=16)",
        &["backend", "shards", "secs", "msgs/sec"],
    );
    let mut local_secs = Vec::new();
    for backend in ["local", "inprocess", "loopback"] {
        for &s in &sweep {
            let cfg = EngineConfig::new(plan.clone(), bd).with_shards(s);
            let builder = AggregatorBuilder::new(cfg, 77);
            let mut agg: Box<dyn Aggregator> = match backend {
                "local" => builder.local(),
                "inprocess" => builder.in_process(),
                _ => builder.loopback(),
            }
            .build()
            .expect("build stack");
            let t0 = Instant::now();
            let result = agg.run_round(&RoundInput::Vectors(&inputs), &seeds).expect("round");
            let secs = t0.elapsed().as_secs_f64();
            if backend == "local" {
                local_secs.push((s, secs));
            }
            backend_table.row(&[
                backend.to_string(),
                s.to_string(),
                format!("{secs:.4}"),
                fmt_f(result.traffic.messages as f64 / secs),
            ]);
        }
    }
    println!("{}", backend_table.render());
    let (_, t1) = local_secs[0];
    let &(s_max, t_max) = local_secs.last().unwrap();
    assert!(
        t_max <= t1 * 1.6,
        "S={s_max} round slower than single shard: {t_max:.4}s vs {t1:.4}s"
    );
    println!("fl_round: OK");
}
