//! Quickstart: one private aggregation, narrated step by step — the
//! Figure 2 message flow made concrete.
//!
//!     cargo run --release --example quickstart
//!
//! 1000 users each hold a value in [0,1]; the server learns their sum
//! within the Theorem 1 error bound and nothing else.

use cloak_agg::prelude::*;
use cloak_agg::rng::SplitMix64;
use cloak_agg::util::error::Result;

fn main() -> Result<()> {
    let n = 1_000;
    let (eps, delta) = (1.0, 1e-6);

    // --- plan: the proof's constants for (n, ε, δ) ----------------------
    let plan = ProtocolPlan::theorem1(n, eps, delta)?;
    plan.check_feasibility().expect("the paper's constants are feasible here");
    println!("Invisibility Cloak protocol — Theorem 1 regime");
    println!("  n = {n} users, (ε, δ) = ({eps}, {delta:.0e})");
    println!(
        "  ring Z_N with N = {} ({} bits/message), k = {}, m = {} messages/user",
        plan.modulus,
        plan.message_bits(),
        plan.scale,
        plan.num_messages
    );
    println!(
        "  per-user communication: {} bits  (polylog in n — Fig. 1 last row)",
        plan.bits_per_user()
    );

    // --- users hold private values --------------------------------------
    let mut rng = SplitMix64::seed_from_u64(2026);
    let xs: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
    let truth: f64 = xs.iter().sum();

    // --- encode → shuffle → analyze (Fig. 2) -----------------------------
    let mut pipeline = Pipeline::new(plan.clone(), 42);
    let estimate = pipeline.aggregate(&xs)?;

    println!("\ntrue sum          = {truth:.4}   (never observable by the server)");
    println!("private estimate  = {estimate:.4}");
    println!("absolute error    = {:.4}", (estimate - truth).abs());
    println!("theorem bound     ≈ {:.4} (expected error O(ε⁻¹√log(1/δ)))", plan.error_bound());
    println!(
        "\ntraffic: {} messages / {} bytes total ({:.1} bytes/user)",
        pipeline.last_traffic.messages,
        pipeline.last_traffic.bytes,
        pipeline.last_traffic.bytes_per_user(n)
    );

    // --- the zero-noise regime (Theorem 2) -------------------------------
    let plan2 = ProtocolPlan::theorem2(n, eps, delta)?;
    let k = plan2.scale;
    let mut pipeline2 = Pipeline::new(plan2, 43);
    let estimate2 = pipeline2.aggregate(&xs)?;
    let truth_bar: u64 = xs.iter().map(|&x| (x * k as f64).floor() as u64).sum();
    println!("\nTheorem 2 regime (sum-preserving neighbors): zero added noise");
    println!("  estimate = {estimate2:.6}; discretized truth = {:.6}", truth_bar as f64 / k as f64);
    assert!((estimate2 - truth_bar as f64 / k as f64).abs() < 1e-9);
    println!("  exact up to the 1/k discretization — the 'invisibility cloak' adds no error.");
    Ok(())
}
