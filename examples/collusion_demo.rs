//! Collusion resilience (§2.5, Lemmas 12–13) — what a coalition of
//! colluding users + the server actually learns.
//!
//!     cargo run --release --example collusion_demo
//!
//! 20 users aggregate; coalitions of 0%, 50% and 90% reveal their own
//! messages. The demo shows (a) the coalition can subtract its own
//! contribution and learn the *honest residual sum* — which DP permits —
//! and (b) the honest users' individual values remain hidden: every
//! honest sub-multiset consistent with the residual sum is (near-)equally
//! likely, measured by the γ-smoothness of honest share unions.

use cloak_agg::arith::modring::ModRing;
use cloak_agg::coordinator::{honest_residual_sum, Coordinator, CoordinatorConfig};
use cloak_agg::params::{NeighborNotion, ProtocolPlan};
use cloak_agg::privacy::smoothness;
use cloak_agg::report::Table;
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};
use cloak_agg::util::error::Result;

fn main() -> Result<()> {
    let n = 20usize;
    let scale = 100u64;
    // small modulus so the smoothness measurement can enumerate Z_N, but
    // still > 3nk as Algorithm 2 requires
    let modulus = {
        let v = 3 * n as u64 * scale + 101;
        if v % 2 == 0 {
            v + 1
        } else {
            v
        }
    };
    let m = 12usize;
    let plan = ProtocolPlan::custom(n, 1.0, 1e-6, NeighborNotion::SumPreserving, modulus, scale, m);
    let ring = ModRing::new(modulus);

    let mut rng = SplitMix64::seed_from_u64(5);
    let xs: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
    let truth_bar: u64 = xs.iter().map(|&x| (x * scale as f64).floor() as u64).sum();

    let mut table = Table::new(
        "collusion resilience (n=20, Lemma 12 setting)",
        &["coalition", "honest users", "estimate exact?", "honest residual learned", "honest pair γ-smooth"],
    );

    for frac in [0.0, 0.5, 0.9] {
        let colluders = (n as f64 * frac) as usize;
        let mut coord =
            Coordinator::new(CoordinatorConfig::new(plan.clone(), 1), 7 + colluders as u64);
        coord.registry_mut().mark_colluding(
            &(0..colluders as u32).collect::<Vec<_>>(),
        );
        let inputs: Vec<Vec<f64>> = xs.iter().map(|&x| vec![x]).collect();
        let (result, views) = coord.run_round_with_views(&inputs)?;

        // (a) total estimate stays exact regardless of collusion
        let exact = (result.estimates[0] - truth_bar as f64 / scale as f64).abs() < 1e-9;

        // the coalition removes its own messages -> honest residual sum
        let total_raw =
            views.iter().fold(0u64, |acc, v| ring.add(acc, ring.sum(&v.shares)));
        let residual = honest_residual_sum(ring, total_raw, &views[..colluders]);
        let want_residual: u64 = xs[colluders..]
            .iter()
            .map(|&x| (x * scale as f64).floor() as u64)
            .sum();
        assert_eq!(residual, ring.reduce(want_residual), "coalition algebra");

        // (b) privacy of the honest subset: the union of any two honest
        // users' share multisets is γ-smooth, so their *split* of the
        // residual is hidden (Lemma 3 applied within the honest subset).
        let gamma = if n - colluders >= 2 {
            let mut e = views[colluders].shares.clone();
            e.extend(views[colluders + 1].shares.iter().copied());
            let rep = smoothness::measure(&e, modulus);
            rep.gamma
        } else {
            f64::NAN
        };

        table.row(&[
            format!("{:.0}%", frac * 100.0),
            (n - colluders).to_string(),
            if exact { "yes".into() } else { "NO".into() },
            format!("{residual} (= Σ honest x̄, allowed by DP)"),
            format!("γ = {gamma:.3}"),
        ]);
    }
    println!("{}", table.emit("collusion_demo.txt"));
    println!(
        "interpretation: the coalition learns only the honest *sum* — every\n\
         honest user's value stays cloaked (small γ ⇒ subset sums near-uniform,\n\
         the Lemma 12 bound β^(n-1) applies to the honest subset unchanged)."
    );
    println!("collusion_demo: OK");
    Ok(())
}
