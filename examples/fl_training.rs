//! End-to-end federated training — the repo's full-system driver.
//!
//!     make artifacts && cargo run --release --example fl_training
//!
//! All three layers compose here:
//!   L1  Pallas cloak/modsum kernels (baked into the HLO artifacts),
//!   L2  JAX MLP fwd/bwd — executed from Rust via PJRT (never Python),
//!   L3  the Rust coordinator: encode → mixnet shuffle → analyze.
//!
//! Workload: 24 clients, non-IID synthetic 8-class task, 120 rounds of
//! FedSGD with secure aggregation (Theorem 2 regime: exact sums, the
//! Bonawitz-replacement configuration), loss + accuracy + privacy budget
//! logged every 10 rounds. Results land in EXPERIMENTS.md §FL.
//!
//! Flags (positional-free, all optional):
//!     --rounds N      training rounds           (default 120)
//!     --clients N     cohort size               (default 24)
//!     --notion 1|2    Thm 1 (DP noise) | Thm 2  (default 2)
//!     --eps F         per-round epsilon         (default 1.0)

use cloak_agg::cli::Args;
use cloak_agg::ensure;
use cloak_agg::fl::{data::SyntheticTask, FlConfig, FlDriver};
use cloak_agg::params::NeighborNotion;
use cloak_agg::report::Table;
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};
use cloak_agg::runtime::{Manifest, Runtime};
use cloak_agg::util::error::Result;

fn init_params(mf: &Manifest, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::seed_from_u64(seed ^ 0x1217);
    let mut p = Vec::with_capacity(mf.param_count);
    let s1 = (2.0 / mf.input_dim as f64).sqrt();
    for _ in 0..mf.input_dim * mf.hidden_dim {
        p.push(((rng.gen_f64() * 2.0 - 1.0) * s1) as f32);
    }
    p.extend(std::iter::repeat(0f32).take(mf.hidden_dim));
    let s2 = (2.0 / mf.hidden_dim as f64).sqrt();
    for _ in 0..mf.hidden_dim * mf.num_classes {
        p.push(((rng.gen_f64() * 2.0 - 1.0) * s2) as f32);
    }
    p.extend(std::iter::repeat(0f32).take(mf.num_classes));
    p
}

fn accuracy(rt: &Runtime, params: &[f32], task: &SyntheticTask, batches: usize) -> f64 {
    let mf = &rt.manifest;
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..batches {
        let eval = task.client_batch(9_000 + b, 777, mf.batch_size);
        let preds = rt.fl_predict(params, &eval.x).expect("predict");
        for (p, y) in preds.iter().zip(&eval.y) {
            correct += (p == y) as usize;
            total += 1;
        }
    }
    correct as f64 / total as f64
}

fn main() -> Result<()> {
    // examples take flags directly; prepend an implicit subcommand
    let args = Args::parse(
        std::iter::once("run".to_string()).chain(std::env::args().skip(1)),
        &["run"],
        &["rounds", "clients", "notion", "eps"],
    )?;
    let rounds = args.get_usize("rounds", 120)?;
    let clients = args.get_usize("clients", 24)?;
    let notion = if args.get_usize("notion", 2)? == 1 {
        NeighborNotion::SingleUser
    } else {
        NeighborNotion::SumPreserving
    };
    let eps = args.get_f64("eps", 1.0)?;

    let rt = Runtime::load("artifacts")?;
    let mf = rt.manifest.clone();
    println!(
        "L2 model: {} params (MLP {}→{}→{}), batch {} | L1 kernel: N={}, m={}",
        mf.param_count, mf.input_dim, mf.hidden_dim, mf.num_classes, mf.batch_size,
        mf.modulus, mf.num_messages
    );
    println!(
        "FL: {clients} clients × {rounds} rounds, notion = {:?}, ε/round = {eps}\n",
        notion
    );

    let task = SyntheticTask::new(mf.input_dim, mf.num_classes, 7);
    let cfg = FlConfig {
        clients,
        rounds,
        eps_round: eps,
        delta_round: 1e-6,
        lr: 1.2,
        momentum: 0.8,
        batch_size: mf.batch_size,
        pad_to: mf.encode_dim,
        scale: 1 << 16,
        notion,
        // kernel profile: the artifact's (N, k=2^16, m) — int32-safe lanes
        custom_plan: Some((mf.modulus, 1 << 16, mf.num_messages)),
    };
    let mut driver = FlDriver::new(cfg, &rt, init_params(&mf, 3), 42)?;

    let mut table = Table::new(
        "federated training (loss curve)",
        &["round", "loss", "acc", "|g|", "msgs/round", "eps_spent", "sec/round"],
    );
    let t0 = std::time::Instant::now();
    for r in 0..rounds {
        let batches: Vec<_> =
            (0..clients).map(|c| task.client_batch(c, r as u64, mf.batch_size)).collect();
        let log = driver.run_round(&batches)?;
        if r % 10 == 0 || r + 1 == rounds {
            let acc = accuracy(&rt, driver.server.params(), &task, 8);
            table.row(&[
                r.to_string(),
                format!("{:.4}", log.mean_loss),
                format!("{:.3}", acc),
                format!("{:.4}", log.grad_norm),
                log.messages.to_string(),
                format!("{:.2}", log.eps_spent),
                format!("{:.3}", log.wall_seconds),
            ]);
        }
    }
    println!("{}", table.emit("fl_training.txt"));
    let total = t0.elapsed().as_secs_f64();
    let first = driver.logs.first().unwrap().mean_loss;
    let last = driver.logs.last().unwrap().mean_loss;
    let final_acc = accuracy(&rt, driver.server.params(), &task, 16);
    println!("loss {first:.4} → {last:.4} over {rounds} rounds ({total:.1}s wall)");
    println!("final eval accuracy = {final_acc:.3} (chance = {:.3})", 1.0 / mf.num_classes as f64);
    let spent = driver.accountant().best(1e-6);
    println!("privacy spent: ε = {:.2}, δ = {:.1e} ({} rounds composed)",
        spent.epsilon, spent.delta, driver.accountant().num_rounds());
    ensure!(last < first * 0.8, "training must reduce loss");
    ensure!(final_acc > 2.0 / mf.num_classes as f64, "must beat chance");
    println!("fl_training: OK");
    Ok(())
}
