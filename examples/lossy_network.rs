//! Streaming aggregation over a lossy network — the dropout story.
//!
//! A cohort of clients cloak-encodes its inputs and streams them to the
//! coordinator as wire frames through a `SimNet` that loses, duplicates,
//! delays and reorders traffic. The round closes on a deadline with
//! whoever made it; the aggregator renormalizes the estimate over the
//! actual participants, so the answer is *exact for the surviving cohort*
//! in the Theorem 2 regime — no bias from who happened to drop. The
//! finale runs the very same lossy scenario with the coordinator's rounds
//! executing on a multi-host cluster stack (built declaratively by
//! `AggregatorBuilder`) — the frontends are generic over the `Aggregator`
//! facade, so nothing else changes and the estimates stay bit-identical.
//!
//!     cargo run --release --example lossy_network

use cloak_agg::aggregator::{Aggregator, AggregatorBuilder};
use cloak_agg::coordinator::{Coordinator, CoordinatorConfig};
use cloak_agg::params::ProtocolPlan;
use cloak_agg::report::Table;
use cloak_agg::transport::channel::{SimNet, SimNetConfig};

fn main() {
    let n = 200;
    let d = 4;
    let plan = ProtocolPlan::exact_secure_agg(n, 100, 8);
    let k = plan.scale;
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..d).map(|j| ((i * 7 + j * 13) % 100) as f64 / 100.0).collect())
        .collect();

    let mut table = Table::new(
        "dropout sweep — streaming rounds, renormalized estimates",
        &["loss", "participants", "dropped", "dup frames", "est[0]", "survivor sum", "|err|"],
    );

    for (step, &loss) in [0.0, 0.1, 0.25, 0.5].iter().enumerate() {
        let mut coord = Coordinator::new(CoordinatorConfig::new(plan.clone(), d), 42);
        // a couple of graceful dropouts on top of the network loss
        let mut mask = vec![false; n];
        mask[0] = true;
        mask[n / 2] = true;
        let mut net = SimNet::new(
            SimNetConfig::new(1000 + step as u64).with_loss(loss).with_duplicate(0.05),
        );
        coord.stream_cohort(&inputs, &mask, &mut net).expect("send cohort");
        let out = coord.run_round_streaming(&mut net, n / 4, 1.0).expect("streaming round");

        let survivor_sum: f64 = out
            .contributed
            .iter()
            .map(|&i| (inputs[i as usize][0] * k as f64).floor() as u64)
            .sum::<u64>() as f64
            / k as f64;
        let err = (out.result.estimates[0] - survivor_sum).abs();
        table.row(&[
            format!("{loss:.2}"),
            out.result.participants.to_string(),
            out.dropped.len().to_string(),
            out.duplicate_frames.to_string(),
            format!("{:.2}", out.result.estimates[0]),
            format!("{survivor_sum:.2}"),
            format!("{err:.2e}"),
        ]);
        assert!(err < 1e-9, "estimate must be exact over the surviving cohort");
        assert_eq!(out.contributed.len() + out.dropped.len(), n, "everyone accounted for");
    }
    println!("{}", table.render());

    // Shard invariance under dropout: the same lossy scenario (same
    // SimNet seed, same drop mask) through a 1-shard and a 4-shard engine
    // produces bit-identical estimates.
    let run = |shards: usize| {
        let mut cfg = CoordinatorConfig::new(plan.clone(), d);
        cfg.shards = shards;
        let mut coord = Coordinator::new(cfg, 7);
        let mut net = SimNet::new(SimNetConfig::new(99).with_loss(0.1).with_duplicate(0.05));
        coord.stream_cohort(&inputs, &vec![false; n], &mut net).expect("send cohort");
        coord.run_round_streaming(&mut net, n / 4, 1.0).expect("streaming round")
    };
    let s1 = run(1);
    let s4 = run(4);
    assert_eq!(s1.contributed, s4.contributed, "same survivors");
    assert_eq!(s1.result.estimates, s4.result.estimates, "bit-identical across shard counts");
    println!(
        "shard invariance: S=1 and S=4 agree on {} survivors, {} instances",
        s1.result.participants,
        s1.result.estimates.len()
    );

    // Backend invariance: the same scenario again, but the coordinator's
    // rounds execute on a cluster stack — shard servers behind the full
    // wire codec — built in one declarative line. Same SimNet seed, same
    // survivors, bit-identical estimates.
    let mut cfg = CoordinatorConfig::new(plan.clone(), d);
    cfg.shards = 4;
    let stack = AggregatorBuilder::new(cfg.engine_config(), 7)
        .loopback()
        .build()
        .expect("cluster stack");
    let mut coord = Coordinator::with_aggregator(cfg, 7, stack).expect("cluster coordinator");
    let mut net = SimNet::new(SimNetConfig::new(99).with_loss(0.1).with_duplicate(0.05));
    coord.stream_cohort(&inputs, &vec![false; n], &mut net).expect("send cohort");
    let sc = coord.run_round_streaming(&mut net, n / 4, 1.0).expect("streaming round");
    assert_eq!(sc.contributed, s4.contributed, "same survivors on the cluster stack");
    assert_eq!(sc.result.estimates, s4.result.estimates, "bit-identical over the cluster");
    println!(
        "backend invariance: the same dropout round over a {}-shard cluster stack matches",
        coord.aggregator().shards()
    );
    println!("lossy_network: OK");
}
