//! Private histogram — §1.2's "statistical queries over a distributed
//! data set": every histogram bucket is one statistical query in [0,1],
//! aggregated through the Invisibility Cloak coordinator in a single
//! round (one aggregation instance per bucket).
//!
//!     cargo run --release --example private_histogram
//!
//! 2000 users each hold one category (a zipf-ish distribution over 16
//! buckets); the server reconstructs the histogram under Theorem 1 DP
//! without ever seeing an individual's category.

use cloak_agg::coordinator::{Coordinator, CoordinatorConfig};
use cloak_agg::ensure;
use cloak_agg::params::ProtocolPlan;
use cloak_agg::report::Table;
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};
use cloak_agg::util::error::Result;

fn main() -> Result<()> {
    // Thm 1 noise is flat in n (~166 per bucket at ε=1, δ=1e-6), so the
    // relative accuracy *improves* with cohort size — the paper's whole
    // point. 10^4 users over 8 buckets puts the mode ≈ 3700 ≫ noise.
    let n = 10_000;
    let buckets = 8usize;
    let (eps, delta) = (1.0, 1e-6);

    // zipf-ish category draw per user
    let mut rng = SplitMix64::seed_from_u64(11);
    let weights: Vec<f64> = (1..=buckets).map(|r| 1.0 / r as f64).collect();
    let wsum: f64 = weights.iter().sum();
    let mut categories = Vec::with_capacity(n);
    for _ in 0..n {
        let mut u = rng.gen_f64() * wsum;
        let mut cat = 0;
        for (i, &w) in weights.iter().enumerate() {
            if u < w {
                cat = i;
                break;
            }
            u -= w;
            cat = i;
        }
        categories.push(cat);
    }
    let mut truth = vec![0usize; buckets];
    for &c in &categories {
        truth[c] += 1;
    }

    // one-hot inputs: bucket j of user i is 1 iff category(i) == j
    let inputs: Vec<Vec<f64>> = categories
        .iter()
        .map(|&c| (0..buckets).map(|j| (j == c) as u8 as f64).collect())
        .collect();

    // Theorem 1 plan — per-bucket DP noise
    let plan = ProtocolPlan::theorem1(n, eps, delta)?;
    println!(
        "n={n} users, {buckets} buckets, (ε,δ)=({eps},{delta:.0e}); m={} messages/user/bucket",
        plan.num_messages
    );
    let mut coord = Coordinator::new(CoordinatorConfig::new(plan.clone(), buckets), 99);
    let result = coord.run_round(&inputs)?;

    let mut table =
        Table::new("private histogram (zipf over 8 buckets)", &["bucket", "true", "private", "err"]);
    let mut max_err = 0f64;
    for j in 0..buckets {
        let err = (result.estimates[j] - truth[j] as f64).abs();
        max_err = max_err.max(err);
        table.row(&[
            j.to_string(),
            truth[j].to_string(),
            format!("{:.1}", result.estimates[j]),
            format!("{err:.1}"),
        ]);
    }
    println!("{}", table.emit("private_histogram.txt"));
    println!("max bucket error = {max_err:.1} (Thm 1 expected ≈ {:.1} per bucket)", plan.error_bound());
    println!(
        "round moved {} messages in {:.2}s",
        result.traffic.messages, result.wall_seconds
    );

    // Sanity: the heavy buckets must be ordered correctly despite noise.
    let mut order: Vec<usize> = (0..buckets).collect();
    order.sort_by(|&a, &b| result.estimates[b].partial_cmp(&result.estimates[a]).unwrap());
    ensure!(order[0] == 0, "bucket 0 is the zipf mode");
    // and the total mass is ≈ n
    let mass: f64 = result.estimates.iter().sum();
    ensure!((mass - n as f64).abs() < n as f64 * 0.2, "mass {mass}");
    println!("private_histogram: OK");
    Ok(())
}
