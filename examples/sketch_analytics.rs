//! Private sketch analytics — §1.2 "Private Sketching and Statistical
//! Learning": linear sketches computed locally, aggregated through the
//! shard-parallel Invisibility Cloak engine (one shard per slice of the
//! sketch width), decoded server-side.
//!
//!     cargo run --release --example sketch_analytics
//!
//! 600 clients each hold a handful of items from a zipf distribution.
//! One aggregation round per structure:
//!   * CountMin cells        → heavy hitters + point frequencies
//!   * occupancy bitmap      → distinct-element count
//!   * dyadic histogram      → quantiles of a numeric attribute
//! The server sees only aggregated (cloaked) sketch cells.

use cloak_agg::engine::{DerivedClientSeeds, Engine, EngineConfig, RoundInput};
use cloak_agg::ensure;
use cloak_agg::params::ProtocolPlan;
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};
use cloak_agg::sketch::countmin::CountMin;
use cloak_agg::sketch::distinct::DistinctCounter;
use cloak_agg::sketch::quantiles::QuantileSketch;
use cloak_agg::sketch::{denormalize_sum, normalize_cells};
use cloak_agg::util::error::Result;

const N_CLIENTS: usize = 600;
const ITEMS_PER_CLIENT: usize = 8;
const CELL_CAP: u64 = 8; // max count a single client can put in one cell

/// A Theorem 2 (exact secure-aggregation) engine over `width` instances —
/// sketch analytics needs no registry or streaming ingestion, so it
/// constructs the engine directly rather than going through a coordinator.
fn cell_engine(n: usize, width: usize, seed: u64) -> Engine {
    // Theorem 2 regime: exact totals (secure-aggregation semantics).
    let plan = ProtocolPlan::exact_secure_agg(n, 10 * n as u64, 16);
    Engine::new(EngineConfig::new(plan, width), seed)
}

/// Aggregate per-client cell vectors (each cell in [0, cap]) through the
/// engine; returns the decoded per-cell totals.
fn aggregate_cells_capped(cells_per_client: &[Vec<u64>], cap: u64, seed: u64) -> Vec<f64> {
    let width = cells_per_client[0].len();
    let n = cells_per_client.len();
    let mut engine = cell_engine(n, width, seed);
    let inputs: Vec<Vec<f64>> =
        cells_per_client.iter().map(|c| normalize_cells(c, cap)).collect();
    let result = engine
        .run_round(&RoundInput::Vectors(&inputs), &DerivedClientSeeds::new(seed))
        .expect("aggregation round");
    denormalize_sum(&result.estimates, cap)
}

fn aggregate_cells(cells_per_client: &[Vec<u64>], seed: u64) -> Vec<f64> {
    aggregate_cells_capped(cells_per_client, CELL_CAP, seed)
}

fn main() -> Result<()> {
    let mut rng = SplitMix64::seed_from_u64(31);
    // zipf-ish items over a 1..512 universe + a numeric attribute in [0,1)
    let universe = 512u64;
    let mut all_items: Vec<Vec<u64>> = Vec::with_capacity(N_CLIENTS);
    let mut all_values: Vec<Vec<f64>> = Vec::with_capacity(N_CLIENTS);
    for _ in 0..N_CLIENTS {
        let mut items = Vec::with_capacity(ITEMS_PER_CLIENT);
        let mut values = Vec::with_capacity(ITEMS_PER_CLIENT);
        for _ in 0..ITEMS_PER_CLIENT {
            // crude zipf: item = universe / (1 + pareto-ish draw)
            let u = rng.gen_f64().max(1e-9);
            let item = ((universe as f64) * u * u * u) as u64 % universe;
            items.push(item);
            values.push(rng.gen_f64().powi(2)); // skewed attribute
        }
        all_items.push(items);
        all_values.push(values);
    }

    // ground truth
    let mut freq = std::collections::HashMap::new();
    let mut distinct_true = std::collections::HashSet::new();
    let mut values_flat: Vec<f64> = Vec::new();
    for (items, values) in all_items.iter().zip(&all_values) {
        for &it in items {
            *freq.entry(it).or_insert(0u64) += 1;
            distinct_true.insert(it);
        }
        values_flat.extend(values);
    }
    values_flat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let true_median = values_flat[values_flat.len() / 2];

    // --- 1. CountMin → frequencies & heavy hitters ----------------------
    let (width, depth, seed) = (256usize, 4usize, 77u64);
    let clients_cm: Vec<Vec<u64>> = all_items
        .iter()
        .map(|items| {
            let mut cm = CountMin::new(width, depth, seed);
            for &it in items {
                cm.insert(it);
            }
            cm.cells().to_vec()
        })
        .collect();
    let agg_cm = aggregate_cells(&clients_cm, 1);
    let probe = CountMin::new(width, depth, seed); // same geometry for decode
    let mut top: Vec<(u64, u64)> = freq.iter().map(|(&k, &v)| (k, v)).collect();
    top.sort_by(|a, b| b.1.cmp(&a.1));
    let mut table = Table::new("private CountMin: top-5 items", &["item", "true", "private est"]);
    for &(item, count) in top.iter().take(5) {
        table.row(&[
            item.to_string(),
            count.to_string(),
            fmt_f(probe.query_cells(&agg_cm, item)),
        ]);
    }
    println!("{}", table.emit("sketch_analytics.txt"));
    for &(item, count) in top.iter().take(3) {
        let est = probe.query_cells(&agg_cm, item);
        ensure!(est >= count as f64 * 0.9, "CountMin never underestimates (modulo cap)");
        ensure!(est <= count as f64 + 0.02 * (N_CLIENTS * ITEMS_PER_CLIENT) as f64);
    }

    // --- 2. occupancy bitmap → distinct count ----------------------------
    let dw = 2048usize;
    let clients_dc: Vec<Vec<u64>> = all_items
        .iter()
        .map(|items| {
            let mut dc = DistinctCounter::new(dw, 99);
            for &it in items {
                dc.insert(it);
            }
            dc.cells()
        })
        .collect();
    let agg_dc = aggregate_cells(&clients_dc, 2);
    let distinct_est = DistinctCounter::estimate_from_occupancy(&agg_dc, dw);
    println!(
        "distinct elements: true = {}, private estimate = {:.0}",
        distinct_true.len(),
        distinct_est
    );
    ensure!(
        (distinct_est - distinct_true.len() as f64).abs() < 0.15 * distinct_true.len() as f64
    );

    // --- 3. dyadic histogram → quantiles ---------------------------------
    let bins = 128usize;
    let clients_q: Vec<Vec<u64>> = all_values
        .iter()
        .map(|vals| {
            let mut q = QuantileSketch::new(bins);
            for &v in vals {
                q.insert(v);
            }
            q.cells().to_vec()
        })
        .collect();
    let agg_q = aggregate_cells(&clients_q, 3);
    let med = QuantileSketch::quantile_from_cells(&agg_q, 0.5);
    let p90 = QuantileSketch::quantile_from_cells(&agg_q, 0.9);
    println!("median: true = {true_median:.3}, private = {med:.3}; p90 private = {p90:.3}");
    ensure!((med - true_median).abs() < 0.05, "median error");
    ensure!(p90 > med, "quantile monotonicity");

    // --- 4. AMS projections → ℓ₂ norm ------------------------------------
    use cloak_agg::sketch::lp_norm::AmsL2Sketch;
    let reps = 128usize;
    let offset = 64i64; // per-client projections bounded by items/client
    let clients_l2: Vec<Vec<u64>> = all_items
        .iter()
        .map(|items| {
            let mut s = AmsL2Sketch::new(reps, 55);
            for &it in items {
                s.insert(it);
            }
            s.offset_projections(offset)
        })
        .collect();
    // offset cells are in [0, 2*offset]; reuse the aggregation path with a
    // cap of 2*offset per cell
    let n = clients_l2.len();
    let cap = 2 * offset as u64;
    let agg = aggregate_cells_capped(&clients_l2, cap, 4);
    let proj = AmsL2Sketch::decode_aggregate(&agg, n, offset);
    let l2sq_est = AmsL2Sketch::l2_squared_from_projections(&proj);
    let l2sq_true: f64 = freq.values().map(|&c| (c * c) as f64).sum();
    println!(
        "l2^2 of the global frequency vector: true = {:.0}, private = {:.0}",
        l2sq_true, l2sq_est
    );
    ensure!(
        (l2sq_est - l2sq_true).abs() < 0.35 * l2sq_true,
        "l2 estimate out of tolerance"
    );

    println!("sketch_analytics: OK (4 structures privately aggregated over {N_CLIENTS} clients)");
    Ok(())
}
