//! Regenerates Figure 1 — the paper's comparison table — from *measured*
//! runs of every protocol, plus the asymptotic columns from the planners.
//!
//!     cargo run --release --example fig1_report
//!
//! Columns: measured messages/user, measured message size, measured
//! expected error over trials, and the privacy notion — the same rows the
//! paper reports asymptotically. Output is appended to
//! reports/fig1_report.txt (consumed by EXPERIMENTS.md).

use cloak_agg::baselines::{
    balle::BalleProtocol, bonawitz::BonawitzProtocol, central_dp::CentralDpProtocol,
    cheu::CheuProtocol, local_dp::LocalDpProtocol, AggregationProtocol, CloakProtocol,
};
use cloak_agg::report::{fmt_f, Table};
use cloak_agg::rng::{Rng, SeedableRng, SplitMix64};

fn measure(p: &mut dyn AggregationProtocol, n: usize, trials: usize) -> (f64, f64) {
    let mut rng = SplitMix64::seed_from_u64(17);
    let xs: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
    let truth: f64 = xs.iter().sum();
    let mut err_sum = 0.0;
    let mut bytes_per_user = 0.0;
    for _ in 0..trials {
        let (est, traffic) = p.aggregate(&xs);
        err_sum += (est - truth).abs();
        bytes_per_user = traffic.bytes_per_user(n);
    }
    (err_sum / trials as f64, bytes_per_user)
}

fn main() {
    let n = 10_000;
    let (eps, delta) = (1.0, 1e-6);
    let trials = 5;
    println!("regenerating Figure 1 at n = {n}, (ε, δ) = ({eps}, {delta:.0e}), {trials} trials\n");

    let mut rows: Vec<(Box<dyn AggregationProtocol>, &str)> = vec![
        (Box::new(CheuProtocol::new(n, eps, delta, 1)), "single-user"),
        (Box::new(BalleProtocol::new(n, eps, delta, 2)), "single-user"),
        (Box::new(CloakProtocol::theorem1(n, eps, delta, 3)), "single-user"),
        (Box::new(CloakProtocol::theorem2(n, eps, delta, 4)), "sum-preserving"),
        (Box::new(BonawitzProtocol::new(n, 10 * n as u64, 5)), "exact (HbC server)"),
        (Box::new(LocalDpProtocol::new(n, eps, 100, 6)), "single-user (local)"),
        (Box::new(CentralDpProtocol::new(n, eps, 7)), "single-user (curator)"),
    ];

    let mut table = Table::new(
        &format!("Figure 1 (measured) — n={n}, eps={eps}, delta={delta:.0e}"),
        &["protocol", "msgs/user", "bits/msg", "bytes/user", "mean |error|", "privacy"],
    );
    for (p, notion) in rows.iter_mut() {
        let (err, bpu) = measure(p.as_mut(), n, trials);
        table.row(&[
            p.name().into(),
            fmt_f(p.messages_per_user()),
            p.message_bits().to_string(),
            fmt_f(bpu),
            fmt_f(err),
            notion.to_string(),
        ]);
    }
    println!("{}", table.emit("fig1_report.txt"));

    // The qualitative shape the paper claims, asserted:
    let cloak1 = CloakProtocol::theorem1(n, eps, delta, 8);
    let cheu = CheuProtocol::new(n, eps, delta, 9);
    let bona = BonawitzProtocol::new(n, 10 * n as u64, 10);
    assert!(cloak1.messages_per_user() < bona.messages_per_user());
    assert!(cheu.messages_per_user() < bona.messages_per_user());
    println!(
        "\nshape check: cloak msgs/user ({}) grows polylog — rerun with a larger n to see\n\
         the crossover vs cheu's ε√n (messages equal near n ≈ 3·10^5, cloak wins beyond).",
        cloak1.messages_per_user()
    );
    println!("fig1_report: OK");
}
