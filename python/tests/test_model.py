"""L2 model checks: shapes, gradient correctness (finite differences),
clipping bound, prediction consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import ModelConfig

CFG = ModelConfig(input_dim=8, hidden_dim=12, num_classes=4, batch_size=16)


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    flat = model.init_params(k1, CFG)
    x = jax.random.normal(k2, (CFG.batch_size, CFG.input_dim))
    y = jax.random.randint(k3, (CFG.batch_size,), 0, CFG.num_classes)
    return flat, x, y


def test_param_count(data):
    flat, _, _ = data
    assert flat.shape == (CFG.param_count,)
    assert CFG.param_count == 8 * 12 + 12 + 12 * 4 + 4


def test_unpack_roundtrip(data):
    flat, _, _ = data
    p = model.unpack(flat, CFG)
    re = jnp.concatenate([p["w1"].ravel(), p["b1"], p["w2"].ravel(), p["b2"]])
    np.testing.assert_array_equal(np.asarray(re), np.asarray(flat))


def test_loss_finite_and_positive(data):
    flat, x, y = data
    loss = model.loss_fn(flat, x, y, CFG)
    assert np.isfinite(float(loss)) and float(loss) > 0


def test_grad_matches_finite_differences(data):
    flat, x, y = data
    _, g = model.loss_and_grad(flat, x, y, CFG)
    # undo clipping for the FD comparison
    raw = jax.grad(model.loss_fn)(flat, x, y, CFG)
    idx = np.random.default_rng(1).choice(CFG.param_count, size=12, replace=False)
    eps = 1e-3
    for i in idx:
        e = jnp.zeros_like(flat).at[i].set(eps)
        fd = (model.loss_fn(flat + e, x, y, CFG) - model.loss_fn(flat - e, x, y, CFG)) / (2 * eps)
        assert abs(float(fd) - float(raw[i])) < 5e-3, f"coord {i}"


def test_grad_is_clipped(data):
    flat, x, y = data
    _, g = model.loss_and_grad(flat, x, y, CFG)
    assert float(jnp.linalg.norm(g)) <= 1.0 + 1e-5


def test_clip_direction_preserved(data):
    flat, x, y = data
    _, g = model.loss_and_grad(flat, x, y, CFG)
    raw = jax.grad(model.loss_fn)(flat, x, y, CFG)
    cos = float(jnp.dot(g, raw) / (jnp.linalg.norm(g) * jnp.linalg.norm(raw) + 1e-12))
    assert cos > 0.999


def test_predict_matches_logits_argmax(data):
    flat, x, _ = data
    pred = model.predict(flat, x, CFG)
    lg = model.logits_fn(flat, x, CFG)
    np.testing.assert_array_equal(np.asarray(pred), np.argmax(np.asarray(lg), axis=-1))


def test_training_reduces_loss(data):
    """A few SGD steps on the raw gradient must reduce the loss — the L2
    graph is actually trainable (the FL driver relies on this)."""
    flat, x, y = data
    l0 = float(model.loss_fn(flat, x, y, CFG))
    cur = flat
    for _ in range(40):
        _, g = model.loss_and_grad(cur, x, y, CFG)
        cur = cur - 0.5 * g
    l1 = float(model.loss_fn(cur, x, y, CFG))
    assert l1 < l0 * 0.7, (l0, l1)
