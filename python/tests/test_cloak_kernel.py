"""Pallas cloak encoder vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes, moduli and share counts; agreement is bit-exact
(integer kernel). Separate deterministic tests pin the paper's invariants:
row sums reconstruct xbar mod N, and the first m-1 columns pass through.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cloak
from compile.kernels.ref import cloak_encode_ref
from compile.config import DEFAULT

KP = DEFAULT.kernel


def _random_case(rng, d, m, modulus):
    xbar = rng.integers(0, modulus, size=d, dtype=np.int64).astype(np.int32)
    u = rng.integers(0, modulus, size=(d, m - 1), dtype=np.int64).astype(np.int32)
    return jnp.asarray(xbar), jnp.asarray(u)


@settings(max_examples=40, deadline=None)
@given(
    d=st.sampled_from([1, 2, 3, 8, 64, 128, 256]),
    m=st.integers(min_value=4, max_value=24),
    modulus=st.sampled_from([5, 97, 12289, 1 << 20, 536_870_909]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref(d, m, modulus, seed):
    rng = np.random.default_rng(seed)
    xbar, u = _random_case(rng, d, m, modulus)
    got = cloak.cloak_encode(xbar, u, modulus=modulus)
    want = cloak_encode_ref(xbar, u, modulus)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    d=st.sampled_from([4, 32, 256]),
    m=st.integers(min_value=4, max_value=16),
    modulus=st.sampled_from([101, 65537, 536_870_909]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_row_sums_reconstruct_xbar(d, m, modulus, seed):
    """Algorithm 1's defining invariant: sum_j y_j = xbar (mod N)."""
    rng = np.random.default_rng(seed)
    xbar, u = _random_case(rng, d, m, modulus)
    y = np.asarray(cloak.cloak_encode(xbar, u, modulus=modulus), dtype=np.int64)
    np.testing.assert_array_equal(y.sum(axis=1) % modulus, np.asarray(xbar, dtype=np.int64))


def test_uniform_columns_pass_through():
    rng = np.random.default_rng(7)
    xbar, u = _random_case(rng, 128, KP.num_messages, KP.modulus)
    y = cloak.cloak_encode(xbar, u, modulus=KP.modulus)
    np.testing.assert_array_equal(np.asarray(y)[:, :-1], np.asarray(u))


def test_block_grid_equivalence():
    """Tiling must not change results: block_d = d vs block_d < d."""
    rng = np.random.default_rng(11)
    xbar, u = _random_case(rng, 512, 8, KP.modulus)
    a = cloak.cloak_encode(xbar, u, modulus=KP.modulus, block_d=512)
    b = cloak.cloak_encode(xbar, u, modulus=KP.modulus, block_d=64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_output_range():
    rng = np.random.default_rng(13)
    xbar, u = _random_case(rng, 256, KP.num_messages, KP.modulus)
    y = np.asarray(cloak.cloak_encode(xbar, u, modulus=KP.modulus))
    assert y.min() >= 0 and y.max() < KP.modulus


def test_seeded_encode_reconstructs():
    """The AOT artifact entry point: seed -> shares, rows still sum to xbar."""
    d, m = 256, KP.num_messages
    rng = np.random.default_rng(17)
    xbar = jnp.asarray(rng.integers(0, KP.modulus, size=d, dtype=np.int64).astype(np.int32))
    y = np.asarray(
        cloak.cloak_encode_from_seed(
            jnp.int32(42), xbar, modulus=KP.modulus, num_messages=m
        ),
        dtype=np.int64,
    )
    assert y.shape == (d, m)
    np.testing.assert_array_equal(y.sum(axis=1) % KP.modulus, np.asarray(xbar, dtype=np.int64))


def test_seeded_encode_deterministic():
    d, m = 64, 8
    xbar = jnp.zeros((d,), jnp.int32)
    a = cloak.cloak_encode_from_seed(jnp.int32(1), xbar, modulus=KP.modulus, num_messages=m)
    b = cloak.cloak_encode_from_seed(jnp.int32(1), xbar, modulus=KP.modulus, num_messages=m)
    c = cloak.cloak_encode_from_seed(jnp.int32(2), xbar, modulus=KP.modulus, num_messages=m)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_share_marginals_look_uniform():
    """Privacy smoke: each share column's empirical mean ~ N/2 (the
    'invisibility' property: any m-1 shares are uniform)."""
    d, m, N = 4096, 8, 536_870_909
    rng = np.random.default_rng(19)
    xbar = jnp.zeros((d,), jnp.int32)  # worst case: all-zero inputs
    u = jnp.asarray(rng.integers(0, N, size=(d, m - 1), dtype=np.int64).astype(np.int32))
    y = np.asarray(cloak.cloak_encode(xbar, u, modulus=N), dtype=np.float64)
    resid = y[:, -1]
    # mean of Uniform[0,N) is N/2 with sd N/sqrt(12 d) ~ 2.4e6 at d=4096
    assert abs(resid.mean() - N / 2) < 6 * N / np.sqrt(12 * d)


def test_vmem_report_sane():
    r = cloak.vmem_report(4096, 16, block_d=128)
    assert r["vmem_bytes_per_step"] == 128 * 4 + 128 * 15 * 4 + 128 * 16 * 4
    assert r["grid"] == 32
