"""AOT pipeline checks: every artifact lowers to parseable HLO text with
the expected entry signature, and the build is deterministic."""

import json
import os
import re

import pytest

from compile import aot
from compile.config import DEFAULT


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), DEFAULT)
    return str(out), manifest


def test_all_artifacts_written(built):
    out, manifest = built
    for name, fname in manifest["artifacts"].items():
        p = os.path.join(out, fname)
        assert os.path.exists(p), name
        assert os.path.getsize(p) > 200, name


def test_manifest_consistent(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["kernel"]["modulus"] == DEFAULT.kernel.modulus
    assert on_disk["model"]["param_count"] == DEFAULT.model.param_count
    assert set(on_disk["hlo_sha256"]) == set(manifest["artifacts"])


def test_hlo_text_is_hlo(built):
    out, manifest = built
    for fname in manifest["artifacts"].values():
        with open(os.path.join(out, fname)) as f:
            text = f.read()
        assert text.startswith("HloModule"), fname
        assert "ENTRY" in text, fname


def test_entry_shapes(built):
    out, manifest = built
    mc, kp = DEFAULT.model, DEFAULT.kernel
    text = open(os.path.join(out, manifest["artifacts"]["fl_grad"])).read()
    # entry takes (params, x, y)
    assert f"f32[{mc.param_count}]" in text
    assert f"f32[{mc.batch_size},{mc.input_dim}]" in text
    text = open(os.path.join(out, manifest["artifacts"]["cloak_encode"])).read()
    assert f"s32[{DEFAULT.encode_dim},{kp.num_messages}]" in text
    text = open(os.path.join(out, manifest["artifacts"]["cloak_modsum"])).read()
    assert f"s32[{DEFAULT.modsum_rows},{DEFAULT.encode_dim}]" in text


def test_build_deterministic(built, tmp_path):
    """Same config -> byte-identical HLO (sha recorded in manifest)."""
    out, manifest = built
    manifest2 = aot.build(str(tmp_path), DEFAULT)
    assert manifest["hlo_sha256"] == manifest2["hlo_sha256"]


def test_no_mosaic_custom_calls(built):
    """interpret=True must lower Pallas to plain HLO ops — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    out, manifest = built
    for name in ("cloak_encode", "cloak_modsum"):
        text = open(os.path.join(out, manifest["artifacts"][name])).read()
        assert "mosaic" not in text.lower(), name
