"""Pallas modsum (analyzer reduction) vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import modsum
from compile.kernels.ref import modsum_ref
from compile.config import DEFAULT

KP = DEFAULT.kernel


def _case(rng, rows, d, modulus):
    return jnp.asarray(
        rng.integers(0, modulus, size=(rows, d), dtype=np.int64).astype(np.int32)
    )


@settings(max_examples=40, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 7, 64, 256, 1024]),
    d=st.sampled_from([1, 3, 16, 128]),
    modulus=st.sampled_from([5, 97, 65537, 536_870_909]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref(rows, d, modulus, seed):
    rng = np.random.default_rng(seed)
    y = _case(rng, rows, d, modulus)
    got = modsum.modsum(y, modulus=modulus)
    want = modsum_ref(y, modulus)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_grid_equivalence():
    rng = np.random.default_rng(3)
    y = _case(rng, 2048, 64, KP.modulus)
    a = modsum.modsum(y, modulus=KP.modulus, block_rows=2048)
    b = modsum.modsum(y, modulus=KP.modulus, block_rows=128)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_int32_overflow_at_max_entries():
    """All entries N-1: the naive int32 row-sum would overflow at ~4 rows;
    the running conditional-subtract must stay exact."""
    rows, d, N = 64, 8, KP.modulus
    y = jnp.full((rows, d), N - 1, jnp.int32)
    got = np.asarray(modsum.modsum(y, modulus=N), dtype=np.int64)
    want = (rows * (N - 1)) % N
    np.testing.assert_array_equal(got, np.full(d, want))


def test_encoder_then_modsum_recovers_sum():
    """End-to-end L1 pipeline: encode n users' values, stack all shares,
    reduce — recovers the exact discretized sum (Theorem 2 zero-error path)."""
    from compile.kernels import cloak

    n, m, N = 32, 8, 65537
    rng = np.random.default_rng(5)
    xs = rng.integers(0, 100, size=n)
    all_shares = []
    for i, x in enumerate(xs):
        u = jnp.asarray(rng.integers(0, N, size=(1, m - 1), dtype=np.int64).astype(np.int32))
        y = cloak.cloak_encode(jnp.asarray([x], jnp.int32), u, modulus=N)
        all_shares.append(np.asarray(y).reshape(-1, 1))
    stacked = jnp.asarray(np.concatenate(all_shares, axis=0))  # (n*m, 1)
    # shuffle rows — analyzer must be permutation-invariant
    perm = np.random.default_rng(6).permutation(stacked.shape[0])
    zbar = modsum.modsum(stacked[perm], modulus=N)
    assert int(np.asarray(zbar)[0]) == int(xs.sum() % N)


def test_vmem_report_sane():
    r = modsum.vmem_report(4096, 256, block_rows=256)
    assert r["grid"] == 16
    assert r["vmem_bytes_per_step"] == 256 * 256 * 4 + 256 * 4
