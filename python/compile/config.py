"""Shared protocol/model configuration for the AOT compile path.

These constants define the *kernel profile* of the Invisibility Cloak
protocol: the (N, k, m) tuple baked into the Pallas kernels and the FL model
shapes baked into the HLO artifacts. The Rust coordinator reads the same
values from ``artifacts/manifest.json`` (written by ``aot.py``) and
re-validates them against the paper's constraints at plan time.

The paper-faithful regime (Theorems 1-2) picks N ≈ 3kn + 10/δ + 10/ε which
can exceed 2^31 for large n; the *kernel profile* restricts N < 2^30 so all
modular arithmetic stays in int32 lanes (see DESIGN.md §Hardware-Adaptation).
The Rust scalar path supports the full u128 regime.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class KernelProfile:
    """Protocol constants baked into the Pallas kernels."""

    # Modulus of the message ring Z_N. Odd, > 3*n*k, and < 2^30 so that
    # x + y < 2^31 for x, y in [0, N): conditional-subtract stays in int32.
    modulus: int = 536_870_909  # largest prime < 2^29; odd, int32-safe
    # Fixed-point scale: x_bar = floor(x * k).
    scale: int = 1 << 16
    # Messages (shares) per user per scalar.
    num_messages: int = 16

    def __post_init__(self) -> None:
        assert self.modulus % 2 == 1, "N must be odd (Algorithm 2)"
        assert self.modulus < (1 << 30), "kernel profile requires int32-safe N"
        assert self.num_messages >= 4, "Lemma 1 requires m >= 4"


@dataclass(frozen=True)
class ModelConfig:
    """FL workload (L2) shapes: a small MLP classifier."""

    input_dim: int = 32
    hidden_dim: int = 64
    num_classes: int = 8
    batch_size: int = 32  # per-client local batch

    @property
    def param_count(self) -> int:
        d, h, c = self.input_dim, self.hidden_dim, self.num_classes
        return d * h + h + h * c + c


@dataclass(frozen=True)
class AotConfig:
    """Everything baked into artifacts/ — mirrored in manifest.json."""

    kernel: KernelProfile = KernelProfile()
    model: ModelConfig = ModelConfig()
    # Static shape of the vectorized encoder artifact: encodes `encode_dim`
    # scalars at once (the FL driver pads the gradient to a multiple).
    encode_dim: int = 256
    # Static row count of the modsum (analyzer) artifact.
    modsum_rows: int = 4096

    def manifest(self) -> dict:
        return {
            "kernel": asdict(self.kernel),
            "model": asdict(self.model) | {"param_count": self.model.param_count},
            "encode_dim": self.encode_dim,
            "modsum_rows": self.modsum_rows,
            "artifacts": {
                "fl_grad": "fl_grad.hlo.txt",
                "fl_predict": "fl_predict.hlo.txt",
                "cloak_encode": "cloak_encode.hlo.txt",
                "cloak_modsum": "cloak_modsum.hlo.txt",
            },
        }


DEFAULT = AotConfig()
