"""AOT compile path: lower the L2/L1 computations to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 serializes HloModuleProto with 64-bit instruction ids,
which the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser on the Rust side (``HloModuleProto::from_text_file``)
reassigns ids and round-trips cleanly — see /opt/xla-example/README.md.

Run once via ``make artifacts``; Python never executes on the request path.

Usage:
    python -m compile.aot --out-dir ../artifacts [--report]
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import DEFAULT, AotConfig
from .kernels import cloak, modsum
from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fl_grad(cfg: AotConfig):
    mc = cfg.model
    fn = functools.partial(model.loss_and_grad, cfg=mc)
    flat = jax.ShapeDtypeStruct((mc.param_count,), jnp.float32)
    x = jax.ShapeDtypeStruct((mc.batch_size, mc.input_dim), jnp.float32)
    y = jax.ShapeDtypeStruct((mc.batch_size,), jnp.int32)
    return jax.jit(fn).lower(flat, x, y)


def lower_fl_predict(cfg: AotConfig):
    mc = cfg.model
    fn = functools.partial(model.predict, cfg=mc)
    flat = jax.ShapeDtypeStruct((mc.param_count,), jnp.float32)
    x = jax.ShapeDtypeStruct((mc.batch_size, mc.input_dim), jnp.float32)
    # Wrap to return a tuple so every artifact unwraps identically in Rust.
    return jax.jit(lambda f, xx: (fn(f, xx),)).lower(flat, x)


def lower_cloak_encode(cfg: AotConfig):
    kp = cfg.kernel
    fn = functools.partial(
        cloak.cloak_encode_from_seed,
        modulus=kp.modulus,
        num_messages=kp.num_messages,
        interpret=True,
    )
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    xbar = jax.ShapeDtypeStruct((cfg.encode_dim,), jnp.int32)
    return jax.jit(lambda s, xb: (fn(s, xb),)).lower(seed, xbar)


def lower_cloak_modsum(cfg: AotConfig):
    kp = cfg.kernel
    fn = functools.partial(modsum.modsum, modulus=kp.modulus, interpret=True)
    y = jax.ShapeDtypeStruct((cfg.modsum_rows, cfg.encode_dim), jnp.int32)
    return jax.jit(lambda yy: (fn(yy),)).lower(y)


LOWERINGS = {
    "fl_grad": lower_fl_grad,
    "fl_predict": lower_fl_predict,
    "cloak_encode": lower_cloak_encode,
    "cloak_modsum": lower_cloak_modsum,
}


def build(out_dir: str, cfg: AotConfig = DEFAULT, report: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = cfg.manifest()
    manifest["hlo_sha256"] = {}
    for name, lower in LOWERINGS.items():
        text = to_hlo_text(lower(cfg))
        path = os.path.join(out_dir, manifest["artifacts"][name])
        with open(path, "w") as f:
            f.write(text)
        manifest["hlo_sha256"][name] = hashlib.sha256(text.encode()).hexdigest()
        print(f"wrote {path} ({len(text)} chars)")
    if report:
        manifest["vmem_reports"] = [
            cloak.vmem_report(cfg.encode_dim, cfg.kernel.num_messages),
            modsum.vmem_report(cfg.modsum_rows, cfg.encode_dim),
        ]
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--report", action="store_true", help="include VMEM/BlockSpec report")
    args = ap.parse_args()
    build(args.out_dir, DEFAULT, report=args.report)


if __name__ == "__main__":
    main()
