"""L1 Pallas kernel: batched Invisibility Cloak encoder (Algorithm 1).

Given d quantized scalars ``xbar`` and their d x (m-1) uniform shares, emit
the d x m share matrix whose last column is the residual share

    y_m = (xbar - sum_{j<m} y_j) mod N .

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * the share axis m sits in the lane dimension, the scalar axis d streams
    through the grid in blocks of ``block_d`` rows — each (block_d, m) tile
    is VMEM-resident for exactly one pass;
  * ``a mod N`` is a lane-parallel conditional subtract (compare+select),
    never an integer division — the TPU VPU has no div unit;
  * the running sum is kept < N at every step so int32 never overflows
    (requires N < 2^30, enforced by ``config.KernelProfile``).

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so real-TPU lowering is a compile-only target here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cloak_kernel(xbar_ref, u_ref, out_ref, *, modulus: int, num_messages: int):
    """Kernel body for one (block_d, m) tile.

    xbar_ref: int32[block_d]        — quantized inputs for this tile.
    u_ref:    int32[block_d, m-1]   — uniform shares in [0, N).
    out_ref:  int32[block_d, m]     — all m shares.
    """
    m = num_messages
    n_mod = jnp.int32(modulus)

    u = u_ref[...]  # (block_d, m-1)

    def body(j, acc):
        acc = acc + u[:, j]
        # acc, u < N  =>  acc + u < 2N < 2^31: one conditional subtract
        # restores acc < N without division.
        return jnp.where(acc >= n_mod, acc - n_mod, acc)

    total = jax.lax.fori_loop(0, m - 1, body, jnp.zeros_like(xbar_ref[...]))
    # resid = (xbar - total) mod N, again division-free: diff in (-N, N).
    diff = xbar_ref[...] - total
    resid = jnp.where(diff < 0, diff + n_mod, diff)

    out_ref[:, : m - 1] = u
    out_ref[:, m - 1] = resid


def cloak_encode(
    xbar: jnp.ndarray,
    uniforms: jnp.ndarray,
    *,
    modulus: int,
    block_d: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Encode ``d`` scalars into ``d x m`` shares (Algorithm 1, batched).

    Args:
      xbar: int32[d] with entries in [0, N).
      uniforms: int32[d, m-1] with entries in [0, N).
      modulus: ring modulus N (odd, < 2^30).
      block_d: rows per grid step; d must be divisible by block_d or smaller.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      int32[d, m]; every row sums to the corresponding xbar mod N.
    """
    d = xbar.shape[0]
    m = uniforms.shape[1] + 1
    if d <= block_d:
        block_d = d
    assert d % block_d == 0, f"d={d} must be a multiple of block_d={block_d}"
    grid = (d // block_d,)

    kernel = functools.partial(_cloak_kernel, modulus=modulus, num_messages=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d, m - 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_d, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, m), jnp.int32),
        interpret=interpret,
    )(xbar, uniforms)


def draw_uniform_shares(key, d: int, num_messages: int, modulus: int) -> jnp.ndarray:
    """The m-1 uniform Z_N draws per scalar (counter-based threefry)."""
    return jax.random.randint(
        key, (d, num_messages - 1), minval=0, maxval=modulus, dtype=jnp.int32
    )


def cloak_encode_from_seed(
    seed: jnp.ndarray,
    xbar: jnp.ndarray,
    *,
    modulus: int,
    num_messages: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Seed-to-shares convenience used by the AOT artifact: derive the
    uniform shares from an int32 seed on-device, then run the kernel."""
    key = jax.random.PRNGKey(seed)
    u = draw_uniform_shares(key, xbar.shape[0], num_messages, modulus)
    return cloak_encode(xbar, u, modulus=modulus, interpret=interpret)


def vmem_report(d: int, num_messages: int, block_d: int = 128) -> dict:
    """Static VMEM footprint estimate for the chosen BlockSpec (bytes).

    interpret=True gives CPU-numpy timings only, so TPU perf is estimated
    from the tile footprint: one input tile, one uniform tile, one output
    tile, all int32. Reported by ``aot.py --report`` into DESIGN.md §Perf.
    """
    bd = min(block_d, d)
    tile_in = bd * 4
    tile_u = bd * (num_messages - 1) * 4
    tile_out = bd * num_messages * 4
    total = tile_in + tile_u + tile_out
    return {
        "kernel": "cloak_encode",
        "block_d": bd,
        "grid": (d + bd - 1) // bd,
        "vmem_bytes_per_step": total,
        "vmem_mib": total / (1 << 20),
        # VPU ops per tile: (m-1) add+select for the sum, 1 sub+select for
        # the residual => ~2m int32 lane-ops per element.
        "lane_ops_per_element": 2 * num_messages,
    }
