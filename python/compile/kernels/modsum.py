"""L1 Pallas kernel: the analyzer's modular reduction (Algorithm 2 core).

Computes ``sum(y, axis=0) mod N`` over the shuffled message matrix
``y: int32[rows, d]`` — one independent aggregation per column (the FL
driver aggregates each gradient coordinate as its own protocol instance).

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid streams over row blocks; the d axis lives in lanes;
  * the accumulator is re-reduced mod N after every row, so it stays < N
    and int32 never overflows (N < 2^30 from the kernel profile);
  * the partial result is carried across grid steps in the output ref
    (revisited-output accumulation), so the whole reduction is a single
    pallas_call with one VMEM-resident accumulator tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _modsum_kernel(y_ref, out_ref, *, modulus: int, block_rows: int):
    """One grid step: fold ``block_rows`` rows into the running column sums."""
    n_mod = jnp.int32(modulus)
    step = pl.program_id(0)

    y = y_ref[...]  # (block_rows, d_block)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def body(r, acc):
        acc = acc + y[r, :]
        return jnp.where(acc >= n_mod, acc - n_mod, acc)

    acc = jax.lax.fori_loop(0, block_rows, body, out_ref[...])
    out_ref[...] = acc


def modsum(
    y: jnp.ndarray,
    *,
    modulus: int,
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Column sums of ``y`` mod N.

    Args:
      y: int32[rows, d], entries in [0, N).
      modulus: ring modulus N (odd, < 2^30).
      block_rows: rows folded per grid step (rows must divide evenly or be
        smaller than one block).
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      int32[d] with entries in [0, N).
    """
    rows, d = y.shape
    if rows <= block_rows:
        block_rows = rows
    assert rows % block_rows == 0, f"rows={rows} % block_rows={block_rows} != 0"
    grid = (rows // block_rows,)

    kernel = functools.partial(_modsum_kernel, modulus=modulus, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        # Same output block every step => revisited-output accumulator.
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.int32),
        interpret=interpret,
    )(y)


def vmem_report(rows: int, d: int, block_rows: int = 256) -> dict:
    """Static VMEM footprint estimate for the chosen BlockSpec (bytes)."""
    br = min(block_rows, rows)
    tile_in = br * d * 4
    tile_acc = d * 4
    total = tile_in + tile_acc
    return {
        "kernel": "modsum",
        "block_rows": br,
        "grid": (rows + br - 1) // br,
        "vmem_bytes_per_step": total,
        "vmem_mib": total / (1 << 20),
        "lane_ops_per_element": 2,  # add + select per element folded
    }
