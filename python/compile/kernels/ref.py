"""Pure-jnp reference oracle for the Pallas kernels.

Every kernel in this package has an exact, obviously-correct counterpart
here; pytest + hypothesis assert bit-exact agreement (integer kernels) on
swept shapes, dtypes and moduli. The Rust encoder cross-checks against the
same semantics through the integration tests (shared share-stream protocol).
"""

import jax.numpy as jnp
import numpy as np


def cloak_encode_ref(xbar: jnp.ndarray, uniforms: jnp.ndarray, modulus: int) -> jnp.ndarray:
    """Reference Invisibility Cloak encoder (Algorithm 1), vectorized.

    Args:
      xbar: int32[d] — scaled, rounded inputs, each in [0, N).
      uniforms: int32[d, m-1] — the m-1 uniform shares per scalar, in [0, N).
      modulus: the ring modulus N.

    Returns:
      int32[d, m] — all m shares; the last column is the residual
      y_m = (xbar - sum_j y_j) mod N, so each row sums to xbar (mod N).
    """
    # numpy int64 intermediates: this is the oracle, it may be as slow as it
    # likes — and jax's default int is 32-bit (x64 disabled), which would
    # silently overflow here.
    xb = np.asarray(xbar, dtype=np.int64)
    u = np.asarray(uniforms, dtype=np.int64)
    s = u.sum(axis=1)
    resid = np.mod(xb - s, modulus).astype(np.int32)
    return jnp.asarray(np.concatenate([u.astype(np.int32), resid[:, None]], axis=1))


def modsum_ref(y: jnp.ndarray, modulus: int) -> jnp.ndarray:
    """Reference analyzer reduction (Algorithm 2 core): column sums mod N.

    Args:
      y: int32[rows, d] — shuffled messages, one aggregation per column.
      modulus: ring modulus N.

    Returns:
      int32[d] — sum of each column mod N.
    """
    return jnp.asarray(
        np.mod(np.asarray(y, dtype=np.int64).sum(axis=0), modulus).astype(np.int32)
    )


def analyzer_decision_ref(zbar: np.ndarray, n: int, k: int) -> np.ndarray:
    """Algorithm 2's clamping rule, as plain numpy (used in model tests).

    zbar in [0, N); returns the estimate of sum(x_i) in [0, n].
    """
    zbar = np.asarray(zbar, dtype=np.float64)
    out = zbar / k
    out = np.where(zbar > 2 * n * k, 0.0, out)
    out = np.where((zbar > n * k) & (zbar <= 2 * n * k), float(n), out)
    return out
