"""L2: the federated-learning workload — an MLP classifier in JAX.

This is the compute graph the Rust coordinator drives through PJRT: each
simulated client runs ``loss_and_grad`` on its local batch; the flattened
gradient is clipped, quantized and aggregated coordinate-wise through the
Invisibility Cloak protocol (L3 hot path or the L1 Pallas kernels).

Parameters travel as ONE flat f32 vector — the aggregation protocol is
defined over flat coordinate vectors, so the model owns pack/unpack.

Only used at build time: ``aot.py`` lowers ``loss_and_grad`` / ``predict``
to HLO text; Python never runs on the request path.
"""

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


def shapes(cfg: ModelConfig):
    """Parameter tensor shapes, in flat-vector order."""
    d, h, c = cfg.input_dim, cfg.hidden_dim, cfg.num_classes
    return [("w1", (d, h)), ("b1", (h,)), ("w2", (h, c)), ("b2", (c,))]


def unpack(flat: jnp.ndarray, cfg: ModelConfig):
    """Split the flat parameter vector into named tensors."""
    out, off = {}, 0
    for name, shp in shapes(cfg):
        size = 1
        for s in shp:
            size *= s
        out[name] = flat[off : off + size].reshape(shp)
        off += size
    return out


def init_params(key, cfg: ModelConfig) -> jnp.ndarray:
    """He-initialized flat parameter vector."""
    ks = jax.random.split(key, 2)
    d, h, c = cfg.input_dim, cfg.hidden_dim, cfg.num_classes
    w1 = jax.random.normal(ks[0], (d, h)) * jnp.sqrt(2.0 / d)
    w2 = jax.random.normal(ks[1], (h, c)) * jnp.sqrt(2.0 / h)
    return jnp.concatenate(
        [w1.ravel(), jnp.zeros(h), w2.ravel(), jnp.zeros(c)]
    ).astype(jnp.float32)


def logits_fn(flat: jnp.ndarray, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Forward pass: x f32[B, D] -> logits f32[B, C]."""
    p = unpack(flat, cfg)
    hbar = jnp.maximum(x @ p["w1"] + p["b1"], 0.0)
    return hbar @ p["w2"] + p["b2"]


def loss_fn(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Mean softmax cross-entropy; y int32[B] labels."""
    lg = logits_fn(flat, x, cfg)
    logp = jax.nn.log_softmax(lg, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def loss_and_grad(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The per-client step the Rust runtime executes: (loss, grad_flat).

    The gradient is L2-clipped HERE (inside the artifact) to ``clip_norm=1``
    so the value the coordinator quantizes is already bounded — keeping the
    sensitivity bound of the DP analysis independent of Rust-side logic.
    """
    loss, g = jax.value_and_grad(loss_fn)(flat, x, y, cfg)
    norm = jnp.sqrt(jnp.sum(g * g) + 1e-12)
    g = g * jnp.minimum(1.0, 1.0 / norm)
    return loss, g


def predict(flat: jnp.ndarray, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """argmax class prediction, int32[B] — used for server-side eval."""
    return jnp.argmax(logits_fn(flat, x, cfg), axis=-1).astype(jnp.int32)
